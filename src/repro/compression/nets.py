"""Small conv/MLP building blocks for the β-VAE compression codec
(paper Table 7; consumed by ``repro.compression.vae``, DESIGN.md
§10.5), in pure JAX with NCHW conv layouts."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def conv_params(key, c_in, c_out, k):
    w = jax.random.normal(key, (c_out, c_in, k, k)) * jnp.sqrt(
        2.0 / (c_in * k * k))
    return {"w": w.astype(jnp.float32), "b": jnp.zeros((c_out,), jnp.float32)}


def conv(p, x, stride=1, padding=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return y + p["b"][None, :, None, None]


def upconv_params(key, c_in, c_out, k):
    return conv_params(key, c_in, c_out, k)


def upconv(p, x, stride=2, padding=1, out_padding=0):
    """2x nearest-neighbour upsample + conv (resize-conv, the standard
    checkerboard-free substitute for ConvTranspose2d)."""
    b, c, h, w = x.shape
    y = jnp.repeat(jnp.repeat(x, 2, axis=2), 2, axis=3)
    return conv(p, y, 1, padding)


def fc_params(key, d_in, d_out):
    w = jax.random.normal(key, (d_in, d_out)) * jnp.sqrt(1.0 / d_in)
    return {"w": w.astype(jnp.float32), "b": jnp.zeros((d_out,), jnp.float32)}


def fc(p, x):
    return x @ p["w"] + p["b"]
