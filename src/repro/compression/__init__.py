"""Distributed lossy compression with side information (paper Sec. 5)."""

from repro.compression.gaussian import GaussianWZ, run_experiment, simulate_trial
from repro.compression.vae import (
    VAETrainConfig,
    compress_image,
    evaluate_rd,
    init_vae,
    train_vae,
)
from repro.compression.wz import WZCode, make_bins, wz_round

__all__ = [
    "GaussianWZ",
    "VAETrainConfig",
    "WZCode",
    "compress_image",
    "evaluate_rd",
    "init_vae",
    "make_bins",
    "run_experiment",
    "simulate_trial",
    "train_vae",
    "wz_round",
]
