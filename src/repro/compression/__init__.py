"""Distributed lossy compression with side information (paper Sec. 5;
DESIGN.md §10).  ``wz`` is the per-sample oracle; ``pipeline`` is the
batched serving-grade engine on the ``gls_binned_race`` kernel."""

from repro.compression.gaussian import GaussianWZ, run_experiment, simulate_trial
from repro.compression.pipeline import (
    WZBatch,
    batched_race_tables,
    wz_pipeline,
    wz_round_batch,
)
from repro.compression.vae import (
    VAETrainConfig,
    compress_batch,
    compress_image,
    evaluate_rd,
    init_vae,
    train_vae,
)
from repro.compression.wz import WZCode, make_bins, wz_round

__all__ = [
    "GaussianWZ",
    "VAETrainConfig",
    "WZBatch",
    "WZCode",
    "batched_race_tables",
    "compress_batch",
    "compress_image",
    "evaluate_rd",
    "init_vae",
    "make_bins",
    "run_experiment",
    "simulate_trial",
    "train_vae",
    "wz_pipeline",
    "wz_round",
    "wz_round_batch",
]
