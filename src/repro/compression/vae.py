"""β-VAE distributed image compression (paper Sec. 5 "Lossy compression on
MNIST" + App. D.3), adapted to the offline synthetic digit dataset.

Pipeline (mirrors Phan et al. / the paper, Fig. 1; DESIGN.md §10.5):
  * encoder net: source image (right half, 1x28x14) -> Gaussian posterior
    p_{W|A} = N(e1(a), diag(e2(a))) over a 4-d latent; prior p_W = N(0, I).
  * decoder net: (w, projected side-info features) -> reconstruction.
  * projection net: 7x7 side-info crop -> 128-d features.
  * estimator net: (w, side-info) -> sigmoid classifier of joint vs
    product, whose odds h/(1-h) estimate the density ratio
    p_{W|T}(w|t)/p_W(w) — exactly the decoder importance weight λ_p^(k).
  * coding: importance-sampled conditional GLS over N prior draws U_i
    with random bin ids l_i in [0, l_max) (App. C).  ``compress_batch``
    codes a whole batch of images through
    ``repro.compression.pipeline`` — net forwards, stacked race tables
    and ONE ``gls_binned_race`` dispatch in a single jitted program;
    ``compress_image`` is the per-image wrapper.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression import nets as N
from repro.compression.pipeline import chunked_batch_map, wz_round_batch
from repro.compression.wz import make_bins
from repro.optim import adam_init, adam_update

LATENT = 4


# ---------------------------------------------------------------------------
# Networks
# ---------------------------------------------------------------------------


def init_vae(key):
    ks = jax.random.split(key, 16)
    return {
        # Encoder: 1x28x14 -> mu/logvar in R^4.
        "enc": {
            "c1": N.conv_params(ks[0], 1, 64, 3),
            "c2": N.conv_params(ks[1], 64, 64, 3),     # stride 2: 14x7
            "c3": N.conv_params(ks[2], 64, 64, 3),     # stride 2: 7x4
            "f1": N.fc_params(ks[3], 64 * 7 * 4, 256),
            "f2": N.fc_params(ks[4], 256, 2 * LATENT),
        },
        # Decoder: (w 4) + (side feats 128) -> 1x28x14.
        "dec": {
            "f1": N.fc_params(ks[5], LATENT + 128, 256),
            "f2": N.fc_params(ks[6], 256, 64 * 7 * 4),
            "u1": N.upconv_params(ks[7], 64, 32, 3),   # 7x4 -> 14x8
            "u2": N.upconv_params(ks[8], 32, 16, 3),   # 14x8 -> 28x16
            "c_out": N.conv_params(ks[9], 16, 1, 3),
        },
        # Projection: 1x7x7 crop -> 128 features.
        "proj": {
            "c1": N.conv_params(ks[10], 1, 32, 3),
            "c2": N.conv_params(ks[11], 32, 64, 3),    # stride 2: 4x4
            "f1": N.fc_params(ks[12], 64 * 4 * 4, 128),
        },
        # Estimator: (w, side feats) -> logit of joint-vs-product.
        "est": {
            "f1": N.fc_params(ks[13], 128 + LATENT, 128),
            "f2": N.fc_params(ks[14], 128, 128),
            "f3": N.fc_params(ks[15], 128, 1),
        },
    }


def encode(p, img):
    """img: (B, 28, 14) -> (mu, logvar) each (B, 4)."""
    x = img[:, None, :, :]
    x = jax.nn.relu(N.conv(p["c1"], x, 1, 1))
    x = jax.nn.relu(N.conv(p["c2"], x, 2, 1))
    x = jax.nn.relu(N.conv(p["c3"], x, 2, 1))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(N.fc(p["f1"], x))
    out = N.fc(p["f2"], x)
    mu, logvar = out[:, :LATENT], out[:, LATENT:]
    return mu, jnp.clip(logvar, -8.0, 4.0)


def project(p, crop):
    """crop: (B, 7, 7) -> (B, 128)."""
    x = crop[:, None, :, :]
    x = jax.nn.relu(N.conv(p["c1"], x, 1, 1))
    x = jax.nn.relu(N.conv(p["c2"], x, 2, 1))
    x = x.reshape(x.shape[0], -1)
    return jax.nn.relu(N.fc(p["f1"], x))


def decode(p, w, feats):
    """(B,4) latent + (B,128) side features -> (B, 28, 14) in [0,1]."""
    x = jnp.concatenate([w, feats], axis=-1)
    x = jax.nn.relu(N.fc(p["f1"], x))
    x = jax.nn.relu(N.fc(p["f2"], x)).reshape(-1, 64, 7, 4)
    x = jax.nn.relu(N.upconv(p["u1"], x))       # 7x4 -> 14x8
    x = jax.nn.relu(N.upconv(p["u2"], x))       # 14x8 -> 28x16
    x = N.conv(p["c_out"], x, 1, 1)[:, 0, :, :14]  # crop pad: 28x14
    return jax.nn.sigmoid(x)


def estimator_logit(p, w, feats):
    x = jnp.concatenate([feats, w], axis=-1)
    x = jax.nn.leaky_relu(N.fc(p["f1"], x))
    x = jax.nn.leaky_relu(N.fc(p["f2"], x))
    return N.fc(p["f3"], x)[:, 0]


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class VAETrainConfig:
    beta: float = 0.35
    lr: float = 1e-3
    steps: int = 400
    batch: int = 64


def vae_loss(params, key, source, crop, beta):
    mu, logvar = encode(params["enc"], source)
    eps = jax.random.normal(key, mu.shape)
    w = mu + jnp.exp(0.5 * logvar) * eps
    feats = project(params["proj"], crop)
    recon = decode(params["dec"], w, feats)
    mse = jnp.mean(jnp.sum((recon - source) ** 2, axis=(1, 2)))
    kl = 0.5 * jnp.mean(jnp.sum(
        jnp.exp(logvar) + mu ** 2 - 1.0 - logvar, axis=-1))
    # Estimator BCE: joint pairs (w from posterior) vs product pairs
    # (w shuffled across the batch).
    logit_joint = estimator_logit(params["est"], w, feats)
    w_shuf = jnp.roll(w, 1, axis=0)
    logit_prod = estimator_logit(params["est"], w_shuf, feats)
    bce = jnp.mean(jax.nn.softplus(-logit_joint)) + jnp.mean(
        jax.nn.softplus(logit_prod))
    return mse + beta * kl + bce, {"mse": mse, "kl": kl, "bce": bce}


def train_vae(key, images: np.ndarray, cfg: VAETrainConfig, log=print):
    """images: (n, 28, 28) synthetic digits.  Returns trained params."""
    from repro.data.mnist import wz_split
    params = init_vae(jax.random.fold_in(key, 0))
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, key, source, crop):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: vae_loss(p, key, source, crop, cfg.beta),
            has_aux=True)(params)
        params, opt, _ = adam_update(params, grads, opt, cfg.lr)
        return params, opt, loss, metrics

    rng = np.random.default_rng(0)
    for i in range(cfg.steps):
        idx = rng.integers(0, len(images), cfg.batch)
        src, crop = wz_split(images[idx], rng)
        key, sub = jax.random.split(key)
        params, opt, loss, metrics = step(params, opt, sub,
                                          jnp.asarray(src), jnp.asarray(crop))
        if i % 100 == 0 or i == cfg.steps - 1:
            log(f"vae step {i:4d} loss {float(loss):.4f} "
                f"mse {float(metrics['mse']):.4f} kl {float(metrics['kl']):.3f}")
    return params


# ---------------------------------------------------------------------------
# Coding with GLS
# ---------------------------------------------------------------------------


def compress_batch(keys, params, sources, crops, *, n_atoms: int,
                   l_max: int, k: int, shared_sheet: bool = False,
                   backend: str = "xla", interpret: bool | None = None):
    """Compress B sources (B,28,14) for K decoders each (crops
    (B,K,7,7); keys (B,)) as one device program.

    Per image b: atoms U_i ~ p_W = N(0, I_4); encoder weight
    log λ_q,i = log N(U_i; μ(a_b), σ²(a_b)) - log N(U_i; 0, I); decoder
    weight log λ_p,i^(k) = the estimator's joint-vs-product logit
    (log h/(1-h) estimates log p_{W|T}/p_W).  All B·(K+Ke) races resolve
    in ONE ``gls_binned_race`` dispatch (DESIGN.md §10.2).

    Returns (recons (B,K,28,14), match (B,K), mse (B,K))."""
    b = sources.shape[0]
    ks = jax.vmap(lambda kk: jax.random.split(kk, 3))(keys)
    k_atoms, k_bins, k_race = ks[:, 0], ks[:, 1], ks[:, 2]
    atoms = jax.vmap(
        lambda kk: jax.random.normal(kk, (n_atoms, LATENT)))(k_atoms)

    mu, logvar = encode(params["enc"], sources)             # (B, 4)
    var = jnp.exp(logvar)
    # log λ_q,i = log N(U_i; mu, var) - log N(U_i; 0, 1)
    log_q = jnp.sum(-0.5 * (jnp.log(2 * jnp.pi * var)[:, None]
                            + (atoms - mu[:, None]) ** 2 / var[:, None]),
                    axis=-1)                                # (B, N)
    log_prior = jnp.sum(-0.5 * (jnp.log(2 * jnp.pi) + atoms ** 2), axis=-1)
    log_w_enc = log_q - log_prior

    feats = project(params["proj"],
                    crops.reshape(b * k, 7, 7)).reshape(b, k, -1)
    # Estimator odds stand in for λ_p,i^(k) per (atom, decoder).
    def dec_weights(atoms_b, f):
        return estimator_logit(
            params["est"], atoms_b,
            jnp.broadcast_to(f, (n_atoms, f.shape[-1])))
    log_w_dec = jax.vmap(
        lambda atoms_b, feats_b: jax.vmap(
            lambda f: dec_weights(atoms_b, f))(feats_b))(atoms, feats)

    bins = jax.vmap(lambda kk: make_bins(kk, n_atoms, l_max))(k_bins)
    code = wz_round_batch(k_race, log_w_enc, log_w_dec, bins, l_max=l_max,
                          shared_sheet=shared_sheet, backend=backend,
                          interpret=interpret)
    w_dec = jnp.take_along_axis(
        atoms, code.x[..., None], axis=1)                   # (B, K, 4)
    recons = decode(params["dec"], w_dec.reshape(b * k, LATENT),
                    feats.reshape(b * k, -1)).reshape(b, k, 28, 14)
    mse = jnp.mean((recons - sources[:, None]) ** 2, axis=(2, 3))
    return recons, code.match, mse


def compress_image(key, params, source, crops, *, n_atoms: int,
                   l_max: int, k: int, shared_sheet: bool = False,
                   backend: str = "xla", interpret: bool | None = None):
    """Compress ONE source (28,14) for K decoders with crops (K,7,7) —
    the B=1 lane of ``compress_batch`` (bit-identical RNG: vmapped
    jax.random ops equal their unbatched per-lane results).

    Returns (recons (K,28,14), match (K,), mse_best)."""
    recons, match, mse = compress_batch(
        key[None], params, source[None], crops[None], n_atoms=n_atoms,
        l_max=l_max, k=k, shared_sheet=shared_sheet, backend=backend,
        interpret=interpret)
    return recons[0], match[0], jnp.min(mse[0])


def evaluate_rd(key, params, images: np.ndarray, *, n_atoms: int = 512,
                l_max: int = 16, k: int = 2, trials: int = 128,
                shared_sheet: bool = False, seed: int = 0,
                backend: str = "xla", interpret: bool | None = None,
                batch_size: int = 64):
    """Rate-distortion point over `trials` random test images.

    Test images and crops are prepared host-side, then coded in
    fixed-size ``compress_batch`` chunks (the tail chunk padded and
    discarded) — one compiled program and one race dispatch per chunk
    instead of one host round-trip per image."""
    from repro.data.mnist import wz_split
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(images), trials)
    sources, crops = [], []
    for j in idx:
        srcs, crop0 = wz_split(np.repeat(images[j:j + 1], k, 0), rng)
        sources.append(srcs[0])
        crops.append(crop0)
    sources = jnp.asarray(np.stack(sources))            # (T, 28, 14)
    crops = jnp.asarray(np.stack(crops))                # (T, K, 7, 7)

    def batch_fn(kk, s, c):
        _, match, mse = compress_batch(
            kk, params, s, c, n_atoms=n_atoms, l_max=l_max, k=k,
            shared_sheet=shared_sheet, backend=backend, interpret=interpret)
        return match, jnp.min(mse, axis=1)   # recons stay on device

    match, mse = chunked_batch_map(
        jax.jit(batch_fn), (jax.random.split(key, trials), sources, crops),
        trials, batch_size)
    return {"rate_bits": float(np.log2(l_max)), "mse": float(np.mean(mse)),
            "match_prob_any": float(np.mean(match.any(axis=1)))}
