"""Synthetic Gaussian source experiment (paper Sec. 5 + App. D.2).

  A ~ N(0,1);  T_k = A + ζ_k, ζ_k ~ N(0, σ²_{T|A});
  encoder target  p_{W|A}(.|a) = N(a, σ²_{W|A});
  decoder target  p_{W|T}(.|t) = N(t/σ²_T, σ²_W - 1/σ²_T);
  MMSE reconstruction  g(w,t) = (σ²_ζ w + σ²_η t)/(σ²_η+σ²_ζ+σ²_η σ²_ζ).

Importance atoms are N prior draws U_i ~ p_W = N(0, σ²_W) (App. C); rate
R = log2(l_max) bits/sample; the final estimate is the best among the K
decoders (oracle selection — the paper's "at least one decoder succeeds"
semantics)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.wz import make_bins, wz_round


@dataclasses.dataclass(frozen=True)
class GaussianWZ:
    sigma2_w_given_a: float = 0.01   # permitted distortion at the encoder
    sigma2_t_given_a: float = 0.5    # side-info noise
    n_atoms: int = 4096              # importance-sample count N

    @property
    def sigma2_w(self) -> float:
        return 1.0 + self.sigma2_w_given_a

    @property
    def sigma2_t(self) -> float:
        return 1.0 + self.sigma2_t_given_a

    def decoder_target(self, t):
        mu = t / self.sigma2_t
        var = self.sigma2_w - 1.0 / self.sigma2_t
        return mu, var

    def mmse(self, w, t):
        s_eta = self.sigma2_w_given_a
        s_zeta = self.sigma2_t_given_a
        return (s_zeta * w + s_eta * t) / (s_eta + s_zeta + s_eta * s_zeta)


def _log_normal(x, mu, var):
    return -0.5 * (jnp.log(2 * jnp.pi * var) + (x - mu) ** 2 / var)


def simulate_trial(key: jax.Array, cfg: GaussianWZ, k: int, l_max: int,
                   shared_sheet: bool = False):
    """One compression round.  Returns (match (K,), sq_err_best, sq_errs)."""
    k_a, k_t, k_u, k_bins, k_race = jax.random.split(key, 5)
    a = jax.random.normal(k_a)
    t = a + jnp.sqrt(cfg.sigma2_t_given_a) * jax.random.normal(k_t, (k,))
    atoms = jnp.sqrt(cfg.sigma2_w) * jax.random.normal(k_u, (cfg.n_atoms,))

    # Encoder weights: log p_{W|A}(U_i|a) - log p_W(U_i).
    log_w_enc = (_log_normal(atoms, a, cfg.sigma2_w_given_a)
                 - _log_normal(atoms, 0.0, cfg.sigma2_w))
    # Decoder weights per k.
    mu_t, var_t = cfg.decoder_target(t)
    log_w_dec = (_log_normal(atoms[None, :], mu_t[:, None], var_t)
                 - _log_normal(atoms[None, :], 0.0, cfg.sigma2_w))

    bins = make_bins(k_bins, cfg.n_atoms, l_max)
    code = wz_round(k_race, log_w_enc, log_w_dec, bins, k,
                    shared_sheet=shared_sheet)
    w_hat = atoms[code.x]                     # (K,) decoder outputs
    a_hat = cfg.mmse(w_hat, t)                # (K,) reconstructions
    sq = (a_hat - a) ** 2
    return code.match, jnp.min(sq), sq


def run_experiment(key: jax.Array, cfg: GaussianWZ, k: int, l_max: int,
                   trials: int, shared_sheet: bool = False):
    """Vectorized trials.  Returns dict with matching prob + distortion."""
    keys = jax.random.split(key, trials)
    fn = jax.jit(jax.vmap(lambda kk: simulate_trial(
        kk, cfg, k, l_max, shared_sheet)), static_argnums=())
    match, best_sq, _ = fn(keys)
    any_match = jnp.any(match, axis=-1)
    return {
        "match_prob_any": float(jnp.mean(any_match)),
        "match_prob_each": float(jnp.mean(match)),
        "distortion": float(jnp.mean(best_sq)),
        "distortion_db": float(10 * jnp.log10(jnp.mean(best_sq))),
        "rate_bits": float(np.log2(l_max)),
    }
