"""Synthetic Gaussian source experiment (paper Sec. 5 + App. D.2;
DESIGN.md §10.5).

  A ~ N(0,1);  T_k = A + ζ_k, ζ_k ~ N(0, σ²_{T|A});
  encoder target  p_{W|A}(.|a) = N(a, σ²_{W|A});
  decoder target  p_{W|T}(.|t) = N(t/σ²_T, σ²_W - 1/σ²_T);
  MMSE reconstruction  g(w,t) = (σ²_ζ w + σ²_η t)/(σ²_η+σ²_ζ+σ²_η σ²_ζ).

Importance atoms are N prior draws U_i ~ p_W = N(0, σ²_W) (App. C); rate
R = log2(l_max) bits/sample; the final estimate is the best among the K
decoders (oracle selection — the paper's "at least one decoder succeeds"
semantics).

``simulate_trial`` is the per-sample oracle (one host-driven
``wz_round``); ``run_experiment`` batches the trial loop through
``repro.compression.pipeline`` — weight construction, the stacked race
tables and the single ``gls_binned_race`` dispatch all fuse into one
jitted device program per chunk of trials.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.pipeline import chunked_batch_map, wz_round_batch
from repro.compression.wz import make_bins, wz_round
from repro.core.bounds import wz_error_upper_bound
from repro.kernels.gls_race.ops import resolve_race_mode

_LN2 = float(np.log(2.0))


@dataclasses.dataclass(frozen=True)
class GaussianWZ:
    sigma2_w_given_a: float = 0.01   # permitted distortion at the encoder
    sigma2_t_given_a: float = 0.5    # side-info noise
    n_atoms: int = 4096              # importance-sample count N

    @property
    def sigma2_w(self) -> float:
        return 1.0 + self.sigma2_w_given_a

    @property
    def sigma2_t(self) -> float:
        return 1.0 + self.sigma2_t_given_a

    def decoder_target(self, t):
        mu = t / self.sigma2_t
        var = self.sigma2_w - 1.0 / self.sigma2_t
        return mu, var

    def mmse(self, w, t):
        s_eta = self.sigma2_w_given_a
        s_zeta = self.sigma2_t_given_a
        return (s_zeta * w + s_eta * t) / (s_eta + s_zeta + s_eta * s_zeta)


def _log_normal(x, mu, var):
    return -0.5 * (jnp.log(2 * jnp.pi * var) + (x - mu) ** 2 / var)


def _trial_setup(key: jax.Array, cfg: GaussianWZ, k: int, l_max: int):
    """One trial's source, side info, atoms, importance weights and bins.

    Returns (k_race, a, t, atoms, log_w_enc (N,), log_w_dec (K, N),
    bins (N,)) — log λ_q,i for the encoder and log λ_p,i^(k) per decoder
    (App. C notation).  Shared verbatim by the per-sample oracle and the
    batched pipeline (vmapped), so both paths consume identical RNG.
    """
    k_a, k_t, k_u, k_bins, k_race = jax.random.split(key, 5)
    a = jax.random.normal(k_a)
    t = a + jnp.sqrt(cfg.sigma2_t_given_a) * jax.random.normal(k_t, (k,))
    atoms = jnp.sqrt(cfg.sigma2_w) * jax.random.normal(k_u, (cfg.n_atoms,))

    # Encoder weights: log λ_q,i = log p_{W|A}(U_i|a) - log p_W(U_i).
    log_w_enc = (_log_normal(atoms, a, cfg.sigma2_w_given_a)
                 - _log_normal(atoms, 0.0, cfg.sigma2_w))
    # Decoder weights: log λ_p,i^(k) = log p_{W|T}(U_i|t_k) - log p_W(U_i).
    mu_t, var_t = cfg.decoder_target(t)
    log_w_dec = (_log_normal(atoms[None, :], mu_t[:, None], var_t)
                 - _log_normal(atoms[None, :], 0.0, cfg.sigma2_w))

    bins = make_bins(k_bins, cfg.n_atoms, l_max)
    return k_race, a, t, atoms, log_w_enc, log_w_dec, bins


def simulate_trial(key: jax.Array, cfg: GaussianWZ, k: int, l_max: int,
                   shared_sheet: bool = False):
    """One compression round (per-sample oracle path).
    Returns (match (K,), sq_err_best, sq_errs)."""
    k_race, a, t, atoms, log_w_enc, log_w_dec, bins = _trial_setup(
        key, cfg, k, l_max)
    code = wz_round(k_race, log_w_enc, log_w_dec, bins, k,
                    shared_sheet=shared_sheet)
    w_hat = atoms[code.x]                     # (K,) decoder outputs
    a_hat = cfg.mmse(w_hat, t)                # (K,) reconstructions
    sq = (a_hat - a) ** 2
    return code.match, jnp.min(sq), sq


# Sub-batch width for the xla backend's in-program lax.map: per-chunk
# intermediates ((chunk, K, N) score tables) stay cache-resident on CPU
# hosts instead of thrashing through tens of MB per pass.  Chunks
# sequence INSIDE the jitted program — still one host dispatch per
# batch.  On TPU/GPU the pallas backend keeps the single full-batch
# kernel: its VMEM tiling already bounds the working set, and the
# one-kernel-dispatch-per-batch contract is load-bearing there
# (DESIGN.md §10.4).
_DEVICE_CHUNK = 32
# The pallas backend's CPU-fallback leg (sequenced row races, DESIGN.md
# §11) runs with a batch-fitted chunk the same way the kernel runs with
# batch-fitted grids: finer chunks keep the (chunk, K, N) race tables
# cache-resident through the two sequenced reductions — measured ~10%
# over the 32-wide default at the bench shapes (B=256, N=2^14, K=2).
_FALLBACK_CHUNK = 8


def _batch_trials(keys: jax.Array, cfg: GaussianWZ, k: int, l_max: int,
                  shared_sheet: bool, backend: str,
                  interpret: bool | None = None,
                  tile_n: int = None):
    """A batch of trials as ONE device program: vmapped weight models
    feeding ``wz_round_batch`` (one race dispatch on the pallas path),
    then the MMSE reconstructions — nothing touches the host in
    between.  ``tile_n`` passes through to the pallas kernel's atom
    tile (coarser tiles amortize per-program overhead on interpret
    hosts; outputs are tiling-invariant)."""
    def chunk(kk):
        k_race, a, t, atoms, log_w_enc, log_w_dec, bins = jax.vmap(
            lambda one: _trial_setup(one, cfg, k, l_max))(kk)
        code = wz_round_batch(k_race, log_w_enc, log_w_dec, bins,
                              l_max=l_max, shared_sheet=shared_sheet,
                              backend=backend, interpret=interpret,
                              tile_n=tile_n)
        w_hat = jnp.take_along_axis(atoms, code.x, axis=1)    # (B, K)
        a_hat = cfg.mmse(w_hat, t)
        sq = (a_hat - a[:, None]) ** 2
        # Information-density samples i(W;A|T) in bits at the selected
        # atom (the Prop.-4 statistic): log2 of λ_q,Y over the
        # decoder-average λ_p,Y — prior terms cancel in the ratio.
        w_enc_y = jnp.take_along_axis(log_w_enc, code.y[:, None],
                                      axis=1)[:, 0]
        w_dec_y = jnp.take_along_axis(
            log_w_dec, code.y[:, None, None].repeat(k, 1), axis=2)[..., 0]
        info_bits = (w_enc_y - (jax.nn.logsumexp(w_dec_y, axis=1)
                                - jnp.log(float(k)))) / _LN2
        return code.match, jnp.min(sq, axis=1), info_bits

    b = keys.shape[0]
    if backend == "xla":
        width = _DEVICE_CHUNK
    elif resolve_race_mode(interpret) == "fallback":
        width = _FALLBACK_CHUNK
    else:
        width = None            # compiled/interpret: one full-batch kernel
    if width and b > width and b % width == 0:
        outs = jax.lax.map(
            chunk, keys.reshape(b // width, width, *keys.shape[1:]))
        return jax.tree_util.tree_map(
            lambda x: x.reshape(b, *x.shape[2:]), outs)
    return chunk(keys)


def run_experiment(key: jax.Array, cfg: GaussianWZ, k: int, l_max: int,
                   trials: int, shared_sheet: bool = False, *,
                   backend: str = "xla", interpret: bool | None = None,
                   batch_size: int = 512):
    """Batched trials through the Wyner–Ziv pipeline.

    Trials run in fixed-size chunks (one compiled program, the tail
    chunk padded and discarded host-side) so arbitrarily many trials
    stream through bounded device memory.  Returns the matching
    probability + distortion dict, now including ``match_lower_bound`` —
    the Prop.-4 lower bound on ``match_prob_any`` evaluated from the
    empirical information densities (``1 - wz_error_upper_bound``).
    """
    fn = jax.jit(lambda kk: _batch_trials(kk, cfg, k, l_max, shared_sheet,
                                          backend, interpret))
    match, best_sq, infos = chunked_batch_map(
        fn, (jax.random.split(key, trials),), trials, batch_size)

    any_match = match.any(axis=-1)
    return {
        "match_prob_any": float(np.mean(any_match)),
        "match_prob_each": float(np.mean(match)),
        "match_lower_bound": float(
            1.0 - wz_error_upper_bound(jnp.asarray(infos), k, l_max)),
        "distortion": float(np.mean(best_sq)),
        "distortion_db": float(10 * np.log10(np.mean(best_sq))),
        "rate_bits": float(np.log2(l_max)),
    }
