"""Wyner–Ziv-style distributed lossy compression with GLS (paper Sec. 5).

One encoder broadcasts an ``log2(l_max)``-bit message to K decoders, each
holding independent side information.  Samples live on N importance atoms
(prior draws U_1..U_N with bin ids l_1..l_N); the encoder and decoders
race shared Exp(1) sheets over their respective importance weights
(App. C).  ``shared_sheet=True`` gives the paper's baseline where all
decoders reuse sheet 0 (and the encoder races only sheet 0).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class WZCode(NamedTuple):
    y: jax.Array          # encoder-selected atom index
    message: jax.Array    # transmitted bin id  l_y
    x: jax.Array          # (K,) decoder-selected atom indices
    match: jax.Array      # (K,) bool — X^(k) == Y


def _race_tables(key: jax.Array, k: int, n: int) -> jax.Array:
    """log S for K sheets of N Exp(1) races."""
    u = jax.random.uniform(key, (k, n), minval=jnp.finfo(jnp.float32).tiny,
                           maxval=1.0)
    return jnp.log(-jnp.log(u))


def wz_round(
    key: jax.Array,
    log_w_enc: jax.Array,     # (N,)  log λ_q,i  (unnormalized ok)
    log_w_dec: jax.Array,     # (K, N) log p_{W|T}(U_i | t_k)/p_W(U_i)
    bins: jax.Array,          # (N,) int bin ids in [0, l_max)
    k: int,
    shared_sheet: bool = False,
) -> WZCode:
    """One encode/decode round.  Decoder weights are masked to the
    transmitted bin (the 1{l_i = M} indicator)."""
    n = log_w_enc.shape[-1]
    log_s = _race_tables(key, k, n)
    if shared_sheet:
        enc_score = log_s[0] - log_w_enc
        y = jnp.argmin(jnp.where(jnp.isfinite(log_w_enc), enc_score, jnp.inf))
    else:
        enc_score = jnp.min(log_s, axis=0) - log_w_enc
        y = jnp.argmin(jnp.where(jnp.isfinite(log_w_enc), enc_score, jnp.inf))
    message = bins[y]
    bin_mask = bins == message
    dec_w = jnp.where(bin_mask[None, :], log_w_dec, -jnp.inf)
    sheets = log_s[0:1].repeat(k, axis=0) if shared_sheet else log_s
    dec_score = sheets - dec_w
    dec_score = jnp.where(jnp.isfinite(dec_w), dec_score, jnp.inf)
    x = jnp.argmin(dec_score, axis=-1)
    return WZCode(y=y.astype(jnp.int32), message=message,
                  x=x.astype(jnp.int32), match=x == y)


def make_bins(key: jax.Array, n: int, l_max: int) -> jax.Array:
    return jax.random.randint(key, (n,), 0, l_max)
