"""Wyner–Ziv-style distributed lossy compression with GLS (paper Sec. 5,
App. C; DESIGN.md §10).

One encoder broadcasts a ``log2(l_max)``-bit message to K decoders, each
holding independent side information T_k.  Samples live on N importance
atoms — prior draws U_1..U_N ~ p_W with uniformly random bin ids
l_1..l_N in [0, l_max) (App. C's random binning).  The encoder races
shared Exp(1) sheets over the importance weights

    λ_q,i = p_{W|A}(U_i | a) / p_W(U_i)          (encoder target ratio)

selects Y = U_{i*}, and transmits the bin id M = l_{i*}.  Decoder k
races the SAME sheets over its own ratio λ_{p,i}^{(k)} =
p_{W|T}(U_i | t_k) / p_W(U_i) restricted to the transmitted bin via the
indicator ``1{l_i = M}``; a match (X^(k) = Y) reproduces the encoder's
sample exactly.  ``shared_sheet=True`` gives the paper's
common-randomness baseline where all decoders reuse sheet 0 (and the
encoder races only sheet 0) — see DESIGN.md §10.3.

This module is the minimal PER-SAMPLE reference path (the equivalence
oracle).  The batched serving-grade engine — stacked RNG, one fused
``gls_binned_race`` dispatch per batch — lives in
``repro.compression.pipeline``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class WZCode(NamedTuple):
    """One encode/decode outcome (paper App. C notation).

    Attributes:
      y: i32 — encoder-selected atom index i* (the sample Y = U_{i*}).
      message: i32 — transmitted bin id M = l_{i*} (log2(l_max) bits).
      x: i32[K] — decoder-selected atom indices X^(k).
      match: bool[K] — the exact-reproduction events X^(k) == Y.
    """

    y: jax.Array
    message: jax.Array
    x: jax.Array
    match: jax.Array


def _race_tables(key: jax.Array, k: int, n: int) -> jax.Array:
    """log S for K shared sheets of N Exp(1) race times (App. C).

    Uses ``jax.random.exponential`` (inverse-CDF, full support) rather
    than a hand-rolled ``log(-log U)`` over a tiny-clamped uniform: the
    old clamp truncated the upper tail of S at ``-log(tiny)`` and the
    double log amplified rounding near u -> 1.  The max() guard only
    protects the measure-zero ``S == 0`` draw from producing -inf;
    tests/test_compression.py pins the resulting race distribution.
    """
    s = jax.random.exponential(key, (k, n))
    return jnp.log(jnp.maximum(s, jnp.finfo(jnp.float32).tiny))


def wz_round(
    key: jax.Array,
    log_w_enc: jax.Array,     # (N,)  log λ_q,i  (unnormalized ok)
    log_w_dec: jax.Array,     # (K, N) log λ_p,i^{(k)} = log p_{W|T}(U_i|t_k)/p_W(U_i)
    bins: jax.Array,          # (N,) int bin ids l_i in [0, l_max)
    k: int,
    shared_sheet: bool = False,
) -> WZCode:
    """One encode/decode round (the per-sample oracle, DESIGN.md §10.1).

    Encoder: Y = argmin_i min_k S_i^(k) / λ_q,i (min over all K sheets;
    sheet 0 only under ``shared_sheet``).  Decoders: weights masked to
    the transmitted bin by the ``1{l_i = M}`` indicator (-inf outside),
    then X^(k) = argmin_i S_i^(k) / λ_p,i^(k).  Atoms with non-finite
    log-weight never win (race time +inf)."""
    n = log_w_enc.shape[-1]
    log_s = _race_tables(key, k, n)
    if shared_sheet:
        enc_score = log_s[0] - log_w_enc
        y = jnp.argmin(jnp.where(jnp.isfinite(log_w_enc), enc_score, jnp.inf))
    else:
        enc_score = jnp.min(log_s, axis=0) - log_w_enc
        y = jnp.argmin(jnp.where(jnp.isfinite(log_w_enc), enc_score, jnp.inf))
    message = bins[y]
    bin_mask = bins == message
    dec_w = jnp.where(bin_mask[None, :], log_w_dec, -jnp.inf)
    sheets = log_s[0:1].repeat(k, axis=0) if shared_sheet else log_s
    dec_score = sheets - dec_w
    dec_score = jnp.where(jnp.isfinite(dec_w), dec_score, jnp.inf)
    x = jnp.argmin(dec_score, axis=-1)
    return WZCode(y=y.astype(jnp.int32), message=message,
                  x=x.astype(jnp.int32), match=x == y)


def make_bins(key: jax.Array, n: int, l_max: int) -> jax.Array:
    """Random binning l_i ~ Unif[0, l_max) of the N atoms (App. C)."""
    return jax.random.randint(key, (n,), 0, l_max)
