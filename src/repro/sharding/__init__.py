"""Distribution layer: per-arch/shape PartitionSpec rules."""

from repro.sharding.rules import (
    batch_shardings,
    cache_shardings,
    cache_spec,
    dp_axes,
    param_spec,
    params_shardings,
)

__all__ = ["batch_shardings", "cache_shardings", "cache_spec", "dp_axes",
           "param_spec", "params_shardings"]
