"""Divisibility-aware sharding rules for every architecture family.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.

Policy (DESIGN.md §5):
  * batch dims shard over the data-parallel axes (pod+data);
  * "wide" param dims (attn heads*hd, FFN hidden, vocab, SSM inner) shard
    over "model" — but only when divisible (smollm's 15 heads, whisper's
    12 etc. fall back to replication on that dim, which is why the vocab
    is padded to a multiple of 256: the LM head always shards);
  * in training mode the contracting/model dim additionally shards over
    the data axes (FSDP) so 405B-class optimizer state fits;
  * KV caches shard sequence over "model" (kv_heads < 16 everywhere) and
    batch over data — the standard long-context serving layout.

Everything is emitted as PartitionSpec pytrees matched per-leaf by name.
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig

# Param-name classification.
_COL_SHARDED = {  # (in, OUT): shard output dim on model, input dim on fsdp
    "wq", "wk", "wv", "w_gate", "w_up", "w_in", "in_proj", "w_x",
    "lru_wa", "lru_wx",
}
_ROW_SHARDED = {  # (IN, out): shard input dim on model, output dim on fsdp
    "wo", "w_down", "w_out", "out_proj",
}
_VOCAB_ROWS = {"embed", "pos_dec"}    # (V, D): V on model
_VOCAB_COLS = {"lm_head"}             # (D, V): V on model
_FSDP_ONLY = {"router", "f1", "f2", "f3", "c1", "c2", "c3"}


def dp_axes(mesh) -> tuple:
    names = tuple(mesh.axis_names)
    return tuple(a for a in names if a in ("pod", "data"))


def _div(n: int, mesh, axis: str) -> bool:
    return n % int(mesh.shape[axis]) == 0


def _fsdp_axis(n: int, mesh, train: bool) -> Optional[tuple]:
    if not train:
        return None
    axes = dp_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in axes]))
    if n % total == 0:
        return axes
    if n % int(mesh.shape["data"]) == 0:
        return ("data",)
    return None


def param_spec(path, leaf, cfg: ModelConfig, mesh, train: bool) -> P:
    """PartitionSpec for one param leaf, identified by its key path."""
    name = None
    for entry in reversed(path):
        if hasattr(entry, "key"):
            name = entry.key
            break
    nd = leaf.ndim
    spec = [None] * nd

    def set_last(axis_idx_from_end, value):
        spec[nd - 1 - axis_idx_from_end] = value

    if name in _VOCAB_ROWS and nd >= 2:
        if _div(leaf.shape[-2], mesh, "model"):
            set_last(1, "model")
        fa = _fsdp_axis(leaf.shape[-1], mesh, train)
        if fa:
            set_last(0, fa)
    elif name in _VOCAB_COLS and nd >= 2:
        if _div(leaf.shape[-1], mesh, "model"):
            set_last(0, "model")
        fa = _fsdp_axis(leaf.shape[-2], mesh, train)
        if fa:
            set_last(1, fa)
    elif name in _COL_SHARDED and nd >= 2:
        # Expert weights are (L, E, D, F): prefer EXPERT parallelism over
        # "model" when E divides (all-to-all token dispatch instead of
        # per-layer activation all-reduce; Perf log: granite-moe train_4k,
        # iteration A1).  Falls back to F-sharding (mixtral: E=8 < 16).
        if nd == 4 and cfg.num_experts and                 leaf.shape[1] == cfg.num_experts and                 _div(cfg.num_experts, mesh, "model"):
            spec[1] = "model"
            fa = _fsdp_axis(leaf.shape[-2], mesh, train)
            if fa:
                set_last(1, fa)
        else:
            if _div(leaf.shape[-1], mesh, "model"):
                set_last(0, "model")
            fa = _fsdp_axis(leaf.shape[-2], mesh, train)
            if fa:
                set_last(1, fa)
    elif name in _ROW_SHARDED and nd >= 2:
        if nd == 4 and cfg.num_experts and                 leaf.shape[1] == cfg.num_experts and                 _div(cfg.num_experts, mesh, "model"):
            spec[1] = "model"
            fa = _fsdp_axis(leaf.shape[-1], mesh, train)
            if fa:
                set_last(0, fa)
        else:
            if _div(leaf.shape[-2], mesh, "model"):
                set_last(1, "model")
            fa = _fsdp_axis(leaf.shape[-1], mesh, train)
            if fa:
                set_last(0, fa)
    elif name in _FSDP_ONLY and nd >= 2:
        fa = _fsdp_axis(leaf.shape[-2], mesh, train)
        if fa:
            set_last(1, fa)
    # conv weights, norms, scalars, biases: replicated.
    return P(*spec)


def params_shardings(params_shape, cfg: ModelConfig, mesh, train: bool):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, param_spec(path, leaf, cfg, mesh, train)),
        params_shape)


def batch_shardings(batch_specs: dict, mesh):
    dp = dp_axes(mesh)
    out = {}
    for name, spec in batch_specs.items():
        nd = len(spec.shape)
        b_ok = spec.shape[0] % int(np.prod([mesh.shape[a] for a in dp])) == 0
        axes = [dp if b_ok else None] + [None] * (nd - 1)
        out[name] = NamedSharding(mesh, P(*axes))
    return out


def cache_spec(path, leaf, cfg: ModelConfig, mesh) -> P:
    """KV/SSM cache sharding: batch on data axes, sequence on model."""
    name = None
    for entry in reversed(path):
        if hasattr(entry, "key"):
            name = entry.key
            break
    dp = dp_axes(mesh)
    nd = leaf.ndim
    shape = leaf.shape
    spec = [None] * nd
    if name == "pos" or nd <= 1:
        return P()
    if name in ("k", "v", "ck", "cv"):
        # (..., B, Hkv, T, hd): batch -> data, seq -> model.
        bdim, hdim, tdim = nd - 4, nd - 3, nd - 2
        if shape[bdim] % int(np.prod([mesh.shape[a] for a in dp])) == 0:
            spec[bdim] = dp
        elif shape[bdim] % int(mesh.shape["data"]) == 0:
            spec[bdim] = "data"
        if _div(shape[hdim], mesh, "model"):
            spec[hdim] = "model"
        elif _div(shape[tdim], mesh, "model"):
            spec[tdim] = "model"
        return P(*spec)
    if name == "ssm":
        # (L, B, H, P, N): batch -> data, heads -> model.
        bdim, hdim = nd - 4, nd - 3
        if shape[bdim] % int(mesh.shape["data"]) == 0:
            spec[bdim] = "data"
        if _div(shape[hdim], mesh, "model"):
            spec[hdim] = "model"
        return P(*spec)
    if name == "conv":
        # (..., B, W-1, conv_dim): batch -> data, channels -> model.
        bdim, cdim = nd - 3, nd - 1
        if shape[bdim] % int(mesh.shape["data"]) == 0:
            spec[bdim] = "data"
        if _div(shape[cdim], mesh, "model"):
            spec[cdim] = "model"
        return P(*spec)
    if name == "h":
        # RG-LRU state (..., B, W): batch -> data, width -> model.
        bdim, wdim = nd - 2, nd - 1
        if shape[bdim] % int(mesh.shape["data"]) == 0:
            spec[bdim] = "data"
        if _div(shape[wdim], mesh, "model"):
            spec[wdim] = "model"
        return P(*spec)
    return P(*spec)


def cache_shardings(cache_specs_tree, cfg: ModelConfig, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, cache_spec(path, leaf, cfg, mesh)),
        cache_specs_tree)
