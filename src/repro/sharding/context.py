"""Activation-sharding context: lets the dry-run/launcher inject
``with_sharding_constraint`` specs (e.g. sequence-parallel layer carries)
into model code without the models depending on any mesh.  Outside a
context the hooks are no-ops, so CPU tests/examples are unaffected."""

from __future__ import annotations

import contextlib
import threading
from typing import Dict

import jax

_STATE = threading.local()


def _specs() -> Dict[str, object]:
    return getattr(_STATE, "specs", {})


@contextlib.contextmanager
def activation_sharding(specs: Dict[str, object]):
    """specs: {hook_name: PartitionSpec}.  Active within the block."""
    prev = _specs()
    _STATE.specs = {**prev, **specs}
    try:
        yield
    finally:
        _STATE.specs = prev


def constrain(x, name: str):
    spec = _specs().get(name)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
