"""W8A8 int8 quantized serving path for the dense family (beyond-paper
§Perf iteration B4).

405B decode is weight-streaming-bound (§Perf B); int8 weights halve the
stream.  Weights are per-output-channel symmetric int8; activations are
dynamically quantized per token (max-abs / 127) so the matmuls run
s8 x s8 -> s32 and rescale in f32 — the standard W8A8 recipe, and the
form XLA lowers to native int8 MXU ops on TPU.

Only the big matmuls quantize (attn projections, SwiGLU, LM head); norms
and embeddings stay bf16.  The KV cache quantizes separately — per-KV-
vector int8 arenas via ``quantize_kv`` (DESIGN.md §11), dequantized
inside the attention kernels.

Backend note: ``qdot`` only emits a native s8 x s8 -> s32 ``dot_general``
where the hardware has int8 MXU/tensor-core paths (TPU/GPU).  XLA:CPU
lowers that op 5-8x SLOWER than an f32 GEMM, so on CPU the integer
matmul is emulated in f32 — exact while the contraction depth K keeps
``K * 127^2 < 2^24`` (K <= 1040), which covers every model in this repo.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.stack import scan_blocks

_QNAMES = {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "lm_head"}


def quantize_weight(w: jax.Array):
    """(in, out) -> {"q": int8 (in, out), "s": f32 (out,)} per-channel."""
    w32 = w.astype(jnp.float32)
    scale = jnp.max(jnp.abs(w32), axis=0) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(w32 / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale}


def quantize_params(params: dict) -> dict:
    """Quantize every 2-D matmul weight named in _QNAMES (any stack depth:
    stacked (L, in, out) quantizes per (L, out) channel)."""

    def visit(path, leaf):
        name = None
        for e in reversed(path):
            if hasattr(e, "key"):
                name = e.key
                break
        if name in _QNAMES and leaf.ndim >= 2:
            w32 = leaf.astype(jnp.float32)
            scale = jnp.max(jnp.abs(w32), axis=-2, keepdims=False) / 127.0
            scale = jnp.maximum(scale, 1e-8)
            q = jnp.clip(jnp.round(w32 / scale[..., None, :]), -127, 127)
            return {"q": q.astype(jnp.int8), "s": scale}
        return leaf

    return jax.tree_util.tree_map_with_path(visit, params)


def qdot(x: jax.Array, wq: dict) -> jax.Array:
    """W8A8 matmul: x (..., in) bf16 x int8 (in, out) -> (..., out) bf16.

    Emits native int8 ``dot_general`` on TPU/GPU; on CPU the same
    integer product runs as an f32 GEMM (see module doc) — identical
    results up to the f32-exact contraction bound."""
    x32 = x.astype(jnp.float32)
    sx = jnp.max(jnp.abs(x32), axis=-1, keepdims=True) / 127.0
    sx = jnp.maximum(sx, 1e-8)
    xq = jnp.clip(jnp.round(x32 / sx), -127, 127)
    if jax.default_backend() in ("tpu", "gpu"):
        acc = jax.lax.dot_general(
            xq.astype(jnp.int8), wq["q"],
            dimension_numbers=(((xq.ndim - 1,), (wq["q"].ndim - 2,)),
                               ((), ())),
            preferred_element_type=jnp.int32).astype(jnp.float32)
    else:
        # xq already holds exact integers in f32; both operands are
        # <= 127 in magnitude so the products and partial sums stay
        # integer-exact in the f32 accumulator for K <= 1040.
        acc = xq @ wq["q"].astype(jnp.float32)
    out = acc * sx * wq["s"]
    return out.astype(x.dtype)


def quantize_kv(x: jax.Array):
    """Per-KV-vector symmetric int8 over the trailing (head_dim) axis.

    x: (..., D) -> (int8 (..., D), f32 scale (..., 1)).  The trailing-1
    scale keeps every cache-arena axis op (row gather/scatter on axis 1,
    time growth on axis 3) shape-compatible with the int8 leaf."""
    x32 = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x32), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array,
                  dtype=jnp.float32) -> jax.Array:
    """Inverse of ``quantize_kv``: int8 (..., D) * f32 (..., 1) -> dtype."""
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _is_q(w) -> bool:
    return isinstance(w, dict) and "q" in w and "s" in w


def _dense(x, w):
    return qdot(x, w) if _is_q(w) else x @ w


def _project_qkv_q(p, x, num_heads, kv_heads, head_dim):
    b, s, _ = x.shape
    q = _dense(x, p["wq"]).reshape(b, s, num_heads, head_dim).transpose(0, 2, 1, 3)
    k = _dense(x, p["wk"]).reshape(b, s, kv_heads, head_dim).transpose(0, 2, 1, 3)
    v = _dense(x, p["wv"]).reshape(b, s, kv_heads, head_dim).transpose(0, 2, 1, 3)
    return q, k, v


def _swiglu_q(p, x):
    gate = jax.nn.silu(_dense(x, p["w_gate"]))
    return _dense(gate * _dense(x, p["w_up"]), p["w_down"])


def _block_verify_q(params_l, carry, cache_l, cfg: ModelConfig):
    x, pos = carry
    p = params_l["attn"]
    hd = cfg.resolved_head_dim
    b, m, _ = x.shape
    xin = L.rmsnorm(params_l["attn_norm"], x, cfg.norm_eps)
    q, k, v = _project_qkv_q(p, xin, cfg.num_heads, cfg.kv_heads, hd)
    positions = (pos + jnp.arange(m, dtype=jnp.int32))[None, None, :]
    positions = jnp.broadcast_to(positions, (b, 1, m))
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    new_k = jax.lax.dynamic_update_slice_in_dim(cache_l["k"], k, pos, axis=2)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache_l["v"], v, pos, axis=2)
    out = L.attention(q, new_k, new_v, causal=True, q_offset=pos,
                      kv_len=pos + m)
    bsz, h, s, d = out.shape
    x = x + _dense(out.transpose(0, 2, 1, 3).reshape(bsz, s, h * d), p["wo"])
    x = x + _swiglu_q(params_l["mlp"],
                      L.rmsnorm(params_l["mlp_norm"], x, cfg.norm_eps))
    return (x, pos), {"k": new_k, "v": new_v}


def verify_step_q(params_q: dict, cfg: ModelConfig, tokens: jax.Array,
                  cache: dict):
    """Int8 twin of transformer.verify_step (m tokens vs cache)."""
    assert not cfg.sliding_window
    x = params_q["embed"][tokens]
    pos = cache["pos"]
    fn = functools.partial(_block_verify_q, cfg=cfg)
    layer_cache = {"k": cache["k"], "v": cache["v"]}
    (x, _), new_cache = scan_blocks(params_q["layers"], (x, pos), fn,
                                    cache=layer_cache)
    x = L.rmsnorm(params_q["final_norm"], x, cfg.norm_eps)
    logits = _dense(x, params_q["lm_head"])
    return logits, {"k": new_cache["k"], "v": new_cache["v"],
                    "pos": pos + tokens.shape[1]}
