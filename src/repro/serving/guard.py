"""Round guards, watchdogs, and typed serving errors (DESIGN.md §13).

The serving loop's correctness story is bit-identity: every execution
mode replays the same (uid, blocks)-keyed randomness, so any divergence
is corruption, not noise.  That makes guarding cheap and sharp — a
round's packed fetch either satisfies a short list of exact invariants
or the round is discarded and replayed:

  * token ids in ``[0, vocab)`` and finite,
  * ``0 <= accepted <= L`` with ``len(new_tokens) == accepted + 1``,
  * ``accepted > 0`` implies some draft row is active (the rollback
    invariant the engines already assert).

``GuardViolation`` subclasses ``AssertionError`` deliberately: the
engines' pre-existing invariant assertions and the guard's checks are
the same class of failure (state corruption detected before tokens
stream out), and callers that matched ``AssertionError`` keep working.
The scheduler treats a violation as a poisoning fault — device KV may
hold NaN/Inf garbage, which unlike finite garbage is NOT masked out of
attention reads (0 * NaN = NaN), so recovery scrubs the arenas before
replaying (``CachePool.scrub``).

``RoundWatchdog`` is a soft wall-clock watchdog: a daemon timer flips
``tripped`` while the blocking engine call runs, and the scheduler
raises ``WatchdogTimeout`` AFTER the call returns.  Soft on purpose —
the round's results are valid (just late), so a caller past its retry
budget can accept them instead of livelocking on a genuinely slow
machine (``ServerMetrics.watchdog_accepts``).
"""

from __future__ import annotations

import threading
from typing import Optional, Sequence

import numpy as np


class InvalidRequest(ValueError):
    """A malformed ``submit()``: rejected at the API boundary instead of
    surfacing as a cryptic device-side failure rounds later."""


class GuardViolation(AssertionError):
    """A round produced an outcome violating a serving invariant."""

    kind = "guard"
    phase = "post"

    def __init__(self, msg: str, uid=None):
        super().__init__(msg)
        self.uid = uid


class WatchdogTimeout(RuntimeError):
    """A round overran the per-round wall-clock budget."""

    kind = "watchdog"
    phase = "post"

    def __init__(self, msg: str, uid=None):
        super().__init__(msg)
        self.uid = uid


def validate_prompt(prompt, max_new, vocab: Optional[int]) -> np.ndarray:
    """Validate a ``submit()`` payload; returns the prompt as i32.
    Raises ``InvalidRequest`` on empty prompts, non-integer dtypes,
    ``max_new < 1``, or out-of-vocab token ids."""
    arr = np.asarray(prompt)
    if arr.ndim != 1:
        raise InvalidRequest(
            f"prompt must be a 1-D token sequence, got shape {arr.shape}")
    if arr.size == 0:
        raise InvalidRequest("prompt must contain at least one token")
    if not np.issubdtype(arr.dtype, np.integer):
        raise InvalidRequest(
            f"prompt must have an integer dtype, got {arr.dtype}")
    if not isinstance(max_new, (int, np.integer)) or max_new < 1:
        raise InvalidRequest(f"max_new must be an int >= 1, got {max_new!r}")
    if vocab is not None and arr.size:
        lo, hi = int(arr.min()), int(arr.max())
        if lo < 0 or hi >= vocab:
            raise InvalidRequest(
                f"prompt token ids must lie in [0, {vocab}), got "
                f"range [{lo}, {hi}]")
    return arr.astype(np.int32)


def _finite_in_vocab(tokens: np.ndarray, vocab: Optional[int], what: str,
                     uid) -> None:
    if tokens.size == 0:
        return
    if np.issubdtype(tokens.dtype, np.floating):
        if not np.all(np.isfinite(tokens)):
            raise GuardViolation(
                f"{what}: non-finite token values (NaN/Inf-poisoned "
                "logits reached the fetch)", uid=uid)
        tokens = tokens.astype(np.int64)
    lo, hi = int(tokens.min()), int(tokens.max())
    # vocab None: engine exposes no vocab size — negative ids are still
    # always corrupt, the upper bound is simply unknowable.
    if lo < 0 or (vocab is not None and hi >= vocab):
        raise GuardViolation(
            f"{what}: token ids outside [0, {vocab}) "
            f"(range [{lo}, {hi}])", uid=uid)


def validate_outcome(out, uid, vocab: Optional[int],
                     draft_len: int) -> None:
    """Validate one ``BlockOutcome`` against the serving invariants.
    The scheduler runs this on every guarded round before any token
    streams out (``on_token`` fires at commit — a poisoned round must
    die before commit, not after)."""
    acc = int(out.accepted)
    if not 0 <= acc <= draft_len:
        raise GuardViolation(
            f"uid {uid}: accepted={acc} outside [0, {draft_len}]", uid=uid)
    if len(out.new_tokens) != acc + 1:
        raise GuardViolation(
            f"uid {uid}: {len(out.new_tokens)} tokens for accepted={acc} "
            "(must be accepted + 1)", uid=uid)
    _finite_in_vocab(np.asarray(out.new_tokens), vocab,
                     f"uid {uid}", uid=uid)
    if acc > 0 and out.active is not None \
            and not np.asarray(out.active).any():
        raise GuardViolation(
            f"rollback invariant violated: num_accepted={acc} "
            "but no draft row is active", uid=uid)


def check_packed(host: dict, slot_uids: Sequence, vocab: Optional[int],
                 draft_len: int) -> None:
    """Validate a fused round's raw packed fetch, per advancing session.
    ``slot_uids``: (uid, slot) pairs.  Runs on every fused round (guard
    enabled or not) — it subsumes the engine's former inline rollback-
    invariant assertion and catches device-side corruption (a NaN logit
    row makes the race argmax emit garbage lane/token ids) before the
    engine converts the fetch into per-request outcomes."""
    accepted = np.asarray(host["accepted"])
    tokens = np.asarray(host["tokens"])
    active = np.asarray(host["active"])
    for uid, slot in slot_uids:
        acc = int(accepted[slot])
        if not 0 <= acc <= draft_len:
            raise GuardViolation(
                f"uid {uid}: packed accepted={acc} outside "
                f"[0, {draft_len}]", uid=uid)
        _finite_in_vocab(tokens[slot][:acc + 1], vocab,
                         f"uid {uid}: packed fetch", uid=uid)
        if acc > 0 and not active[slot].any():
            raise GuardViolation(
                f"rollback invariant violated: num_accepted={acc} "
                "but no draft row is active", uid=uid)


class RoundWatchdog:
    """Soft per-round wall-clock watchdog (module docstring).  Use as a
    context manager around the blocking engine call; check ``tripped``
    after the block."""

    def __init__(self, timeout_ms: Optional[float]):
        self.timeout_ms = timeout_ms
        self.tripped = False
        self._timer: Optional[threading.Timer] = None

    def _fire(self) -> None:
        self.tripped = True

    def __enter__(self) -> "RoundWatchdog":
        if self.timeout_ms:
            self._timer = threading.Timer(self.timeout_ms / 1e3, self._fire)
            self._timer.daemon = True
            self._timer.start()
        return self

    def __exit__(self, *exc) -> bool:
        if self._timer is not None:
            self._timer.cancel()
        return False
