"""Deterministic fault injection for chaos serving (DESIGN.md §13).

A ``FaultPlan`` decides, for every (fault kind, request, block, retry
attempt), whether that fault fires — by hashing the tuple, never by
consuming an RNG stream.  Two properties follow:

  * **Reproducible chaos.**  The same plan over the same trace injects
    the same faults in the same rounds, regardless of wall-clock timing
    or execution order; a chaos failure replays exactly.
  * **Retries re-draw.**  The attempt index (the request's retry
    counter) is part of the key, so a replayed round faces a fresh
    draw at the same rate — persistent-failure quarantine is still
    reachable (rate 1.0, or an unlucky seed), but the common case is a
    clean replay, which is what real transient faults look like.

The kinds mirror the real failure surface of the serving stack:

  ``pool_exhausted``   ``PagePoolExhausted`` from the paged arena's
                       pre-round ``reserve`` (pre-dispatch, state clean)
  ``oom``              arena-growth / allocator failure (pre-dispatch)
  ``kernel_dispatch``  a compiled round program dying after dispatch
                       (post: device state advanced, results lost)
  ``nan_logits``       NaN/Inf-poisoned logits corrupting the packed
                       fetch (post + poisoning: arenas must be
                       scrubbed, not just discarded)
  ``slow_round``       a round stalling past the watchdog budget

Pre-call kinds raise before the engine is touched; post-call kinds
fire after the engine call returns, which is exactly when real device
faults surface (the round already mutated session state — recovery
must hard-evict and replay, see scheduler._recover).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional, Tuple

FAULT_KINDS = ("pool_exhausted", "oom", "kernel_dispatch", "nan_logits",
               "slow_round")
# Kinds injected BEFORE the engine call (session state untouched →
# suspend-capable displacement); the rest fire after it returns.
PRE_CALL_KINDS = ("pool_exhausted", "oom")


class InjectedFault(RuntimeError):
    """A fault raised by the injection harness.  ``kind`` names the
    fault class, ``uid`` attributes it to the request whose draw fired
    (bounding its retries), ``phase`` ("pre"/"post") tells recovery
    whether the engine call ran — post-phase faults leave session
    ``pending``/position state advanced, so the victims must be
    hard-evicted and replayed rather than suspended."""

    def __init__(self, kind: str, uid=None, phase: str = "pre"):
        super().__init__(f"injected fault: {kind} (uid={uid})")
        self.kind = kind
        self.uid = uid
        self.phase = phase


def _draw(seed: int, kind: str, uid, block: int, attempt: int) -> float:
    """Uniform in [0, 1), keyed by the full injection coordinate."""
    h = hashlib.blake2b(f"{seed}:{kind}:{uid}:{block}:{attempt}".encode(),
                       digest_size=8).digest()
    return int.from_bytes(h, "big") / 2.0 ** 64


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Per-kind injection rates (probability per advancing request per
    round).  ``slow_ms`` is the stall injected for ``slow_round`` (set
    it above the server's ``round_timeout_ms`` so the watchdog trips).
    ``only_uids`` restricts injection to specific requests — targeted
    chaos for quarantine/ladder tests."""

    seed: int = 0
    pool_exhausted: float = 0.0
    oom: float = 0.0
    kernel_dispatch: float = 0.0
    nan_logits: float = 0.0
    slow_round: float = 0.0
    slow_ms: float = 100.0
    only_uids: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        for kind in FAULT_KINDS:
            rate = getattr(self, kind)
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"{kind} rate {rate} outside [0, 1]")
        if self.slow_ms < 0:
            raise ValueError(f"slow_ms must be >= 0, got {self.slow_ms}")

    @classmethod
    def uniform(cls, rate: float, seed: int = 0, **kw) -> "FaultPlan":
        """Every fault kind at the same rate."""
        return cls(seed=seed, **{k: rate for k in FAULT_KINDS}, **kw)

    def fires(self, kind: str, uid, block: int, attempt: int = 0) -> bool:
        """Deterministic: does ``kind`` fire for (uid, block, attempt)?"""
        rate = getattr(self, kind)
        if rate <= 0.0:
            return False
        if self.only_uids is not None and uid not in self.only_uids:
            return False
        return _draw(self.seed, kind, uid, block, attempt) < rate

    def any_rate(self) -> float:
        return max(getattr(self, k) for k in FAULT_KINDS)


def poison_outcome(out, vocab: int, uid: int):
    """Deterministically corrupt a ``BlockOutcome`` the way NaN/Inf
    logits corrupt a real round: the race argmax over a NaN-poisoned
    score row emits garbage lane/token ids, and downstream counters
    inherit the garbage.  Varies the corruption by uid so the guard's
    range, finiteness, and consistency checks all get exercised."""
    from repro.specdec.engine import BlockOutcome
    toks = list(out.new_tokens)
    acc = int(out.accepted)
    v = 1024 if vocab is None else int(vocab)
    mode = uid % 3
    if mode == 0:
        acc = v + len(toks)                # accepted count corrupted
    elif mode == 1:
        toks[-1] = v + 13                  # token id past the vocab
    else:
        toks[0] = -1                       # negative token id
    return BlockOutcome(new_tokens=toks, accepted=acc,
                        verify_syncs=out.verify_syncs, active=out.active)
