"""Serving-side infrastructure: W8A8 int8 quantized verify path, and
the fault-tolerance layer (deterministic injection, round guards,
watchdogs — DESIGN.md §13)."""

from repro.serving.faults import (
    FAULT_KINDS,
    FaultPlan,
    InjectedFault,
    poison_outcome,
)
from repro.serving.guard import (
    GuardViolation,
    InvalidRequest,
    RoundWatchdog,
    WatchdogTimeout,
    check_packed,
    validate_outcome,
    validate_prompt,
)
from repro.serving.quant import qdot, quantize_params, quantize_weight, verify_step_q

__all__ = [
    "FAULT_KINDS",
    "FaultPlan",
    "GuardViolation",
    "InjectedFault",
    "InvalidRequest",
    "RoundWatchdog",
    "WatchdogTimeout",
    "check_packed",
    "poison_outcome",
    "qdot",
    "quantize_params",
    "quantize_weight",
    "validate_outcome",
    "validate_prompt",
    "verify_step_q",
]
