"""Serving-side optimizations: W8A8 int8 quantized verify path."""

from repro.serving.quant import qdot, quantize_params, quantize_weight, verify_step_q

__all__ = ["qdot", "quantize_params", "quantize_weight", "verify_step_q"]
