"""Fused device-side block verification (paper Sec. 4, Algorithm 2).

The legacy engines verified one token at a time from a host Python loop,
paying two device->host syncs per step (``int(res.token)`` /
``bool(res.accepted)``).  This module runs the ENTIRE L-step verification
loop of Algorithm 2 as one jitted device program:

  * strategy dispatch is lifted to trace time (the strategy string is a
    static argument — each strategy traces its own scan body);
  * early exit is replaced by masked ``alive`` propagation: every step
    computes its candidate token, but carry updates are frozen once a
    rejection has occurred, so emitted positions past the rejection are
    dead lanes, not control flow;
  * the result is ``(tokens (L+1,), num_accepted, bonus, active)`` —
    exactly ``num_accepted + 1`` leading tokens are valid (the residual
    token on rejection, the bonus token Y_{L+1} on full acceptance) —
    fetched with a single host transfer per block.

Race-family strategies ("gls", "gls_strong", "daliri") share a key
structural reduction: the (L+1, K, N) race table is FIXED for the block —
only the (K,) active mask evolves — so the whole table collapses to
per-row (min, argmin) statistics in ONE batched pass, and the sequential
L-step loop runs on (L+1, K) scalars.  ``backend="pallas"`` routes that
pass through the ``kernels/gls_race`` row-race kernel (batched as
(B=L+1, K, N)); ``backend="xla"`` is the interpretable jnp fallback.
Both produce bit-identical outputs (see tests/test_block_verify.py).

Rejection-sampling strategies ("specinfer", "spectr", "single") run their
per-step verifiers inside the same masked ``lax.scan``; they consume
per-step RNG keys identical to the legacy loop's
``jax.random.split(k_strat, L+1)`` stream, so outputs match bit-for-bit.

``legacy_block_verify`` preserves the pre-refactor host loop verbatim as
the equivalence oracle (and as ``verifier_backend="legacy"`` in the
engines, for host-sync-count comparisons).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gls_race.ops import gls_row_race_op
from repro.specdec import verify as V

_TINY = 1e-30

BACKENDS = ("legacy", "xla", "pallas")
RACE_STRATEGIES = ("gls", "gls_strong", "daliri")
# Rejection-sampling strategies: their verifiers consume the drafter's
# step distributions (the race family is drafter-invariant and never
# needs them).
RS_STRATEGIES = ("specinfer", "spectr", "single")


class BlockVerifyResult(NamedTuple):
    tokens: jax.Array        # (L+1,) i32; tokens[:num_accepted+1] valid
    num_accepted: jax.Array  # () i32 — accepted DRAFT tokens this block
    bonus: jax.Array         # () bool — all L accepted, tokens[L] is Y_{L+1}
    active: jax.Array        # (K,) bool — final active mask (loop-exit state)


class HostBlockResult(NamedTuple):
    """Host-side unpacked block outcome (what the engines consume)."""
    new_tokens: list         # python ints, length num_accepted + 1
    num_accepted: int
    active: np.ndarray       # (K,) bool
    host_syncs: int          # device->host transfers spent on verification


# ---------------------------------------------------------------------------
# Race-family core (gls / gls_strong / daliri)
# ---------------------------------------------------------------------------


def _race_row_stats(log_u: jax.Array, q_steps: jax.Array, backend: str,
                    interpret: bool | None):
    """Row statistics of the block race table.

    log_u/q_steps: (L+1, K, N).  Returns (rmin, rarg), each (L+1, K):
    the minimum race time ``log(-log U) - log q`` over the vocab and its
    argmin, per (step, draft) row.  The xla and pallas paths compute the
    same score floats (same masking convention), so their outputs are
    bit-identical — including when the pallas route autodetects the jnp
    fallback (``interpret=None`` off-TPU, DESIGN.md §11).
    """
    log_s = jnp.log(-log_u)
    if backend == "pallas":
        log_q = jnp.where(q_steps > 0,
                          jnp.log(jnp.maximum(q_steps, _TINY)),
                          jnp.float32(-jnp.inf))
        return gls_row_race_op(log_s, log_q, use_kernel=True,
                               interpret=interpret)
    score = log_s - jnp.log(jnp.maximum(q_steps, _TINY))
    score = jnp.where(q_steps > 0, score, jnp.inf)
    return jnp.min(score, axis=-1), jnp.argmin(score, axis=-1).astype(
        jnp.int32)


def _race_block(strategy: str, rmin: jax.Array, rarg: jax.Array,
                draft_tokens: jax.Array, q_all: jax.Array,
                strat_keys: Optional[jax.Array]) -> BlockVerifyResult:
    """L-step scan over (L+1, K) row stats for the race strategies."""
    l1, k = rmin.shape
    l = l1 - 1
    e0 = jnp.zeros((k,), bool).at[0].set(True)

    def step(carry, inp):
        active, alive, num_acc = carry
        rmin_j, rarg_j, d_j = inp
        if strategy == "gls":
            mask = active
        elif strategy == "gls_strong":
            mask = jnp.ones((k,), bool)
        else:  # daliri: race along draft 0's path only
            mask = e0
        masked = jnp.where(mask, rmin_j, jnp.inf)
        k_star = jnp.argmin(masked)
        token = rarg_j[k_star]
        if strategy == "daliri":
            acc = token == d_j[0]
            new_active = e0
        else:
            new_active = active & (d_j == token)
            acc = jnp.any(new_active)
        take = alive & acc
        active = jnp.where(take, new_active, active)
        num_acc = num_acc + take.astype(jnp.int32)
        return (active, alive & acc, num_acc), token

    carry0 = (jnp.ones((k,), bool), jnp.bool_(True), jnp.int32(0))
    (active, alive, num_acc), step_tokens = jax.lax.scan(
        step, carry0, (rmin[:l], rarg[:l], draft_tokens.T))

    # Bonus token Y_{L+1} (only meaningful when all L steps accepted).
    if strategy in ("gls", "gls_strong"):
        act_b = active if strategy == "gls" else jnp.ones((k,), bool)
        masked = jnp.where(act_b, rmin[l], jnp.inf)
        bonus_tok = rarg[l, jnp.argmin(masked)]
    else:  # daliri: legacy falls through to the categorical bonus branch
        k_idx = jnp.argmax(active)
        bonus_tok = jax.random.categorical(
            strat_keys[l],
            jnp.log(jnp.maximum(q_all[k_idx, l], 1e-30))).astype(jnp.int32)

    tokens = jnp.concatenate([step_tokens, bonus_tok[None]])
    return BlockVerifyResult(tokens=tokens, num_accepted=num_acc,
                             bonus=alive, active=active)


# ---------------------------------------------------------------------------
# Rejection-sampling core (specinfer / spectr / single)
# ---------------------------------------------------------------------------


def _rs_block(strategy: str, draft_tokens: jax.Array,
              draft_probs: jax.Array, q_all: jax.Array,
              strat_keys: jax.Array) -> BlockVerifyResult:
    k, l = draft_tokens.shape
    e0 = jnp.zeros((k,), bool).at[0].set(True)
    p_steps = jnp.swapaxes(draft_probs, 0, 1)     # (L, K, N)
    q_steps = jnp.swapaxes(q_all, 0, 1)           # (L+1, K, N)

    def step(carry, inp):
        active, alive, num_acc = carry
        d_j, p_j, q_j, key_j = inp
        if strategy == "specinfer":
            res = V.specinfer_verify(key_j, p_j, d_j, q_j, active)
            new_active = res.new_active
        elif strategy == "spectr":
            res = V.spectr_verify(key_j, p_j, d_j, q_j, active)
            new_active = res.new_active
        else:  # single (Leviathan): draft 0 only, path continues on row 0
            res = V.single_draft_verify(key_j, p_j[0], d_j[0], q_j[0])
            new_active = e0
        take = alive & res.accepted
        active = jnp.where(take, new_active, active)
        num_acc = num_acc + take.astype(jnp.int32)
        return (active, alive & res.accepted, num_acc), res.token

    carry0 = (jnp.ones((k,), bool), jnp.bool_(True), jnp.int32(0))
    (active, alive, num_acc), step_tokens = jax.lax.scan(
        step, carry0,
        (draft_tokens.T, p_steps, q_steps[:l], strat_keys[:l]))

    k_idx = jnp.argmax(active)
    bonus_tok = jax.random.categorical(
        strat_keys[l],
        jnp.log(jnp.maximum(q_all[k_idx, l], 1e-30))).astype(jnp.int32)
    tokens = jnp.concatenate([step_tokens, bonus_tok[None]])
    return BlockVerifyResult(tokens=tokens, num_accepted=num_acc,
                             bonus=alive, active=active)


# ---------------------------------------------------------------------------
# Public fused entry point
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("strategy", "backend", "interpret"))
def block_verify(log_u: jax.Array, draft_tokens: jax.Array,
                 draft_probs: Optional[jax.Array], q_all: jax.Array,
                 strat_keys: Optional[jax.Array], *, strategy: str = "gls",
                 backend: str = "xla",
                 interpret: bool | None = None) -> BlockVerifyResult:
    """One jitted call verifying a whole speculative block.

    log_u:        (L+1, K, N) shared log-uniforms (common random numbers).
    draft_tokens: (K, L) i32 sampled draft continuations.
    draft_probs:  (K, L, N) drafter step distributions (None for the race
                  strategies, which are drafter-invariant by construction).
    q_all:        (K, L+1, N) target distributions along each draft path.
    strat_keys:   (L+1,) PRNG keys — the legacy ``split(k_strat, L+1)``
                  stream (None allowed for gls/gls_strong).
    strategy:     one of the six verification strategies (static).
    backend:      "xla" | "pallas" (static); "pallas" routes the K-way
                  race through the gls_race row kernel.
    """
    if strategy in RACE_STRATEGIES:
        q_steps = jnp.swapaxes(q_all, 0, 1)       # (L+1, K, N)
        rmin, rarg = _race_row_stats(log_u, q_steps, backend, interpret)
        return _race_block(strategy, rmin, rarg, draft_tokens, q_all,
                           strat_keys)
    if strategy in RS_STRATEGIES:
        return _rs_block(strategy, draft_tokens, draft_probs, q_all,
                         strat_keys)
    raise ValueError(f"unknown strategy {strategy!r}")


def block_verify_batched(log_u: jax.Array, draft_tokens: jax.Array,
                         draft_probs: Optional[jax.Array], q_all: jax.Array,
                         strat_keys: jax.Array, *, strategy: str = "gls",
                         backend: str = "xla",
                         interpret: bool | None = None) -> BlockVerifyResult:
    """Batched Algorithm-2 verification for R requests, device-resident.

    The fused-round building block (DESIGN.md §8): every argument is the
    per-request array of ``block_verify`` stacked on a leading R axis
    (log_u (R, L+1, K, N); draft_tokens (R, K, L); draft_probs
    (R, K, L, N) or None; q_all (R, K, L+1, N); strat_keys (R, L+1)
    keys, required — race strategies simply ignore theirs).  Returns a
    BlockVerifyResult whose leaves carry the R axis and performs NO host
    transfer — callers pack it into their round's single fetch.

    For the race family the R and L+1 axes collapse into one
    ``_race_row_stats`` pass of (R*(L+1), K, N) — rows are independent,
    so results are bit-identical to R separate ``block_verify`` calls
    (as are the vmapped scan cores: jax.random ops under vmap equal
    their per-lane unbatched results).  ``backend="legacy"`` is a host
    loop and cannot run here.
    """
    if strategy in RACE_STRATEGIES:
        r, l1, k, n = log_u.shape
        q_steps = jnp.swapaxes(q_all, 1, 2)       # (R, L+1, K, N)
        rmin, rarg = _race_row_stats(log_u.reshape(r * l1, k, n),
                                     q_steps.reshape(r * l1, k, n),
                                     backend, interpret)
        return jax.vmap(
            lambda rm, ra, dt, qa, sk: _race_block(strategy, rm, ra, dt,
                                                   qa, sk))(
            rmin.reshape(r, l1, k), rarg.reshape(r, l1, k),
            draft_tokens, q_all, strat_keys)
    if strategy in RS_STRATEGIES:
        return jax.vmap(
            lambda dt, dp, qa, sk: _rs_block(strategy, dt, dp, qa, sk))(
            draft_tokens, draft_probs, q_all, strat_keys)
    raise ValueError(f"unknown strategy {strategy!r}")


# ---------------------------------------------------------------------------
# Legacy host-loop verifier (the pre-refactor engine code, kept verbatim
# as the equivalence oracle and for host-sync-count comparisons)
# ---------------------------------------------------------------------------


def legacy_block_verify(log_u, draft_tokens, draft_probs, q_all, strat_keys,
                        *, strategy: str) -> HostBlockResult:
    """Per-token host loop with two device syncs per step."""
    k, l = np.asarray(draft_tokens).shape
    n = q_all.shape[-1]
    out_tokens = []
    active = jnp.ones((k,), bool)
    accepted_drafts = 0
    syncs = 0
    for j in range(l):
        q_j = jnp.asarray(q_all[:, j])
        d_j = jnp.asarray(draft_tokens[:, j])
        if strategy == "gls":
            res = V.gls_verify(log_u[j], d_j, q_j, active)
        elif strategy == "gls_strong":
            res = V.gls_verify_strong(log_u[j], d_j, q_j, active)
        elif strategy == "specinfer":
            res = V.specinfer_verify(strat_keys[j],
                                     jnp.asarray(draft_probs[:, j]),
                                     d_j, q_j, active)
        elif strategy == "spectr":
            res = V.spectr_verify(strat_keys[j],
                                  jnp.asarray(draft_probs[:, j]),
                                  d_j, q_j, active)
        elif strategy == "single":
            res = V.single_draft_verify(strat_keys[j],
                                        jnp.asarray(draft_probs[0, j]),
                                        d_j[0], q_j[0])
        elif strategy == "daliri":
            res = V.daliri_verify(log_u[j, 0], d_j[0], q_j[0])
        else:
            raise ValueError(f"unknown strategy {strategy!r}")
        out_tokens.append(int(res.token))
        syncs += 1
        if not bool(res.accepted):
            syncs += 1
            return HostBlockResult(new_tokens=out_tokens,
                                   num_accepted=accepted_drafts,
                                   active=np.asarray(active),
                                   host_syncs=syncs)
        syncs += 1
        accepted_drafts += 1
        active = res.new_active
        if strategy in ("single", "daliri"):
            # Single-draft: continue only along draft 0's path.
            active = jnp.zeros((k,), bool).at[0].set(True)

    # All L draft tokens accepted: emit the bonus token Y_{L+1}.
    q_last = jnp.asarray(q_all[:, l])
    if strategy in ("gls", "gls_strong"):
        act = active if strategy == "gls" else jnp.ones((k,), bool)
        score = jnp.log(-log_u[l]) - jnp.log(jnp.maximum(q_last, 1e-30))
        score = jnp.where(q_last > 0, score, jnp.inf)
        score = jnp.where(act[:, None], score, jnp.inf)
        bonus = int(jnp.argmin(score) % n)
    else:
        k_idx = int(jnp.argmax(active))
        bonus = int(jax.random.categorical(
            strat_keys[l], jnp.log(jnp.maximum(q_last[k_idx], 1e-30))))
        syncs += 1
    syncs += 1
    out_tokens.append(bonus)
    return HostBlockResult(new_tokens=out_tokens,
                           num_accepted=accepted_drafts,
                           active=np.asarray(active), host_syncs=syncs)


def run_block_verify(log_u, draft_tokens, draft_probs, q_all, strat_keys, *,
                     strategy: str, backend: str = "xla",
                     interpret: bool | None = None) -> HostBlockResult:
    """Backend dispatcher shared by both engines: runs the block verifier
    and unpacks to host.  The fused backends spend exactly ONE host
    transfer per block; "legacy" replays the per-token host loop."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown verifier backend {backend!r}")
    if backend == "legacy":
        return legacy_block_verify(log_u, draft_tokens, draft_probs, q_all,
                                   strat_keys, strategy=strategy)
    res = block_verify(log_u, jnp.asarray(draft_tokens), draft_probs, q_all,
                       strat_keys, strategy=strategy, backend=backend,
                       interpret=interpret)
    tokens, num_acc, active = jax.device_get(
        (res.tokens, res.num_accepted, res.active))
    a = int(num_acc)
    return HostBlockResult(new_tokens=[int(t) for t in tokens[:a + 1]],
                           num_accepted=a, active=np.asarray(active),
                           host_syncs=1)
