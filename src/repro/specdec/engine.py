"""Multi-draft speculative decoding engine (paper Sec. 4, Algorithm 2).

Design notes
------------
* Drafts and target are coupled through *common random numbers*: one block
  draws uniforms U[(L+1), K, N]; draft k samples its j-th token by the
  Gumbel race on U[j, k] and the GLS verifier races the target
  distributions on the very same sheet — this is what makes acceptance
  high AND the output conditionally drafter-invariant (Def. 1).
* Model evaluation uses fixed-size token buffers so jitted forwards
  compile once per (batch, buffer) shape: causal attention makes trailing
  garbage harmless.  The target scores all K draft continuations in one
  batched forward (the K dimension rides in the batch), matching how a
  TPU serving deployment folds drafts into the batch (DESIGN.md §3).
  The same core generalizes over R co-scheduled requests: draft buffers
  stack into (R*K, T) forwards, which is what the batched scheduler
  (scheduler.py) rides.
* Verification is FUSED: the whole L-step loop of Algorithm 2 runs as one
  jitted device program (block_verify.py) — one host transfer per block
  instead of two per token.  ``SpecDecConfig.verifier_backend`` selects
  "xla" (default), "pallas" (routes the K-way race through the
  kernels/gls_race row kernel) or "legacy" (the pre-refactor host loop,
  kept as the equivalence oracle).
* Strategies: "gls" (Alg. 2), "gls_strong" (App. B), "specinfer",
  "spectr", "single" (Leviathan), "daliri" (single-draft coupling).
  K heterogeneous drafters with per-drafter temperatures are supported
  for the paper's diverse-drafts experiment (Table 2/4).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import forward
from repro.models.config import ModelConfig
from repro.specdec import verify as V
from repro.specdec.block_verify import (
    BACKENDS,
    RS_STRATEGIES,
    run_block_verify,
)

STRATEGIES = ("gls", "gls_strong", "specinfer", "spectr", "single", "daliri")


@dataclasses.dataclass(frozen=True)
class SpecDecConfig:
    num_drafts: int = 8           # K
    draft_len: int = 4            # L
    strategy: str = "gls"
    target_temp: float = 1.0
    draft_temps: Optional[tuple] = None   # per-drafter; default all 1.0
    top_k: int = 50               # paper uses top-K 50 sampling
    max_new_tokens: int = 64
    verifier_backend: str = "xla"  # "legacy" | "xla" | "pallas"
    # Tri-state (DESIGN.md §11): None autodetects — compiled Pallas on
    # TPU/GPU, the bit-identical jnp fallback elsewhere; True forces the
    # interpreter (kernel body on any backend); False forces compiled.
    pallas_interpret: Optional[bool] = None
    # Route the cached engine's slot-aware decode attention through the
    # kernels/decode_attention Pallas kernel.  Numerically equivalent
    # but NOT bit-equal to the dense path (online-softmax reduction
    # order), so it defaults off wherever bit-identity contracts apply.
    decode_kernel: bool = False
    # Route the cached engine's admission prefill chunks through the
    # kernels/flash_attention Pallas kernel (the causal multi-token
    # use_kernel route of layers.attention).  Same opt-in contract as
    # decode_kernel: numerically equivalent, not bit-equal.
    prefill_kernel: bool = False
    # Quantized serving (DESIGN.md §11): int8 KV arenas in the cached
    # engine's pool (per-vector scales, quantize-on-write) and W8A8
    # target matmuls in the fused-round verify.  Changes logits within
    # quantization tolerance, so the equivalence gate is ACCEPTANCE-RATE
    # statistics, not bit-identity (tests/test_quant_fused.py).
    quant: bool = False
    # Paged KV arena (DESIGN.md §12): the cached engine's pool stores
    # KV in fixed-size pages behind a device-resident page table
    # (models/paged.py) instead of one contiguous arena — buffer growth
    # becomes a table widening, freed requests return their pages, and
    # the scheduler's v2 policy can oversubscribe slots against a fixed
    # page budget.  Opt-in; the contiguous pool stays the bit-identity
    # oracle (all six strategies produce identical tokens either way).
    paged: bool = False
    page_size: int = 64

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}")
        if self.verifier_backend not in BACKENDS:
            raise ValueError(
                f"unknown verifier backend {self.verifier_backend!r}")

    @property
    def temps(self) -> tuple:
        if self.draft_temps is not None:
            assert len(self.draft_temps) == self.num_drafts
            return tuple(self.draft_temps)
        return (1.0,) * self.num_drafts


@dataclasses.dataclass
class GenerationStats:
    output: np.ndarray            # accepted token ids
    blocks: int                   # target model calls
    accepted_drafts: int          # accepted DRAFT tokens (excl. bonus)
    host_syncs: int = 0           # device->host transfers in verification

    @property
    def block_efficiency(self) -> float:
        """Tokens emitted per target call (paper's BE metric)."""
        return len(self.output) / max(self.blocks, 1)


class BlockOutcome(NamedTuple):
    """Host-side outcome of one speculative block for one request."""
    new_tokens: list              # emitted tokens (num_accepted + 1 of them)
    accepted: int                 # accepted draft tokens
    verify_syncs: int             # host transfers spent verifying
    active: np.ndarray            # (K,) final active mask


def probs_from_logits(logits: jax.Array, temp: float, top_k: int,
                      vocab_size: int) -> jax.Array:
    """Temperature + top-k filtered probabilities over the TRUE vocab."""
    logits = logits[..., :vocab_size].astype(jnp.float32)
    if temp <= 0:
        # Greedy as a limiting case: delta on the argmax.
        return jax.nn.one_hot(jnp.argmax(logits, -1), vocab_size)
    logits = logits / temp
    if top_k and top_k < vocab_size:
        # k-th largest via lax.top_k: O(N log k), not a full O(N log N)
        # sort of the 256k-vocab row on every scoring call.
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits >= kth, logits, -jnp.inf)
    return jax.nn.softmax(logits, axis=-1)


def block_randomness(sub: jax.Array, draft_len: int, num_drafts: int,
                     vocab: int):
    """Shared log-uniforms + strategy key stream for one block: the RNG
    contract (DESIGN.md §3.2) every engine path must follow for the
    coupling — and the cross-engine exact-match tests — to hold."""
    k_unif, k_strat = jax.random.split(sub)
    log_u = jnp.log(jax.random.uniform(
        k_unif, (draft_len + 1, num_drafts, vocab),
        minval=np.finfo(np.float32).tiny, maxval=1.0))
    return log_u, jax.random.split(k_strat, draft_len + 1)


@functools.lru_cache(maxsize=None)
def _jitted_buffer_forward(mcfg: ModelConfig):
    """Process-wide jitted buffer forward, one per ModelConfig (frozen,
    hashable).  Engines used to hold per-instance jit closures, so every
    fresh engine re-traced and re-compiled identical forwards — in the
    strategy benchmarks that billed several seconds of XLA compile time
    to whichever strategy happened to run first (the 2x "gls lag" of
    BENCH_specdec.json).  jax.jit's shape-keyed cache on a shared
    callable makes engine construction compile-free after the first."""
    def f(p, t):
        return forward(p, mcfg, {"tokens": t}, remat=False)
    return jax.jit(f)


class SpecDecEngine:
    """Speculative decoding over one target and K (possibly distinct)
    drafters sharing the target's vocabulary."""

    def __init__(self, target: tuple, drafters: Sequence[tuple],
                 cfg: SpecDecConfig):
        self.t_params, self.t_cfg = target
        self.drafters = list(drafters)
        if len(self.drafters) == 1 and cfg.num_drafts > 1:
            self.drafters = self.drafters * cfg.num_drafts
        assert len(self.drafters) == cfg.num_drafts
        self.cfg = cfg
        self.vocab = self.t_cfg.vocab_size
        self._homogeneous = (
            all(d is self.drafters[0] for d in self.drafters)
            and len(set(cfg.temps)) == 1)
        # Serving instrumentation (read by the scheduler / benchmarks).
        self.num_target_forwards = 0
        self.num_draft_forwards = 0
        # Device->host transfers spent materializing draft tokens (one
        # per draft step per block/round; DESIGN.md §7.3 accounting).
        self.num_draft_syncs = 0

    def set_verifier_backend(self, backend: str) -> None:
        """Degradation-ladder rung (scheduler fault recovery, DESIGN.md
        §13): swap the block-verification backend in place.  Token-
        invisible — the backends are exact-equality oracles of one
        another (tests/test_block_verify.py)."""
        self.cfg = dataclasses.replace(self.cfg, verifier_backend=backend)

    # -- jitted, shape-stable model calls ---------------------------------
    def _buffer_forward(self, params, mcfg: ModelConfig, tokens: jax.Array):
        return _jitted_buffer_forward(mcfg)(params, tokens)

    # -- shared drafting / scoring core (R requests stacked) ---------------
    def _block_randomness(self, sub: jax.Array):
        return block_randomness(sub, self.cfg.draft_len,
                                self.cfg.num_drafts, self.vocab)

    def _draft_block(self, log_u_all: jax.Array, bufs: np.ndarray,
                     p0s: np.ndarray):
        """Autoregressive draft loop over R stacked requests.

        log_u_all: (R, L+1, K, N) device; bufs: (R, K, T) host buffers
        (mutated in place); p0s: (R,) prefix lengths.  Returns
        (draft_tokens (R, K, L) host, draft_probs (R, K, L, N) device or
        None).  One drafter forward per step covers all R*K rows when the
        drafters are homogeneous; else one per drafter over the R rows.
        """
        cfg = self.cfg
        r_n, k_n, t_n = bufs.shape
        l_n, n = cfg.draft_len, self.vocab
        need_probs = cfg.strategy in RS_STRATEGIES
        d_tokens = np.zeros((r_n, k_n, l_n), np.int32)
        prob_steps = []
        rows = np.arange(k_n)
        for j in range(l_n):
            pos = p0s + j - 1                                   # (R,)
            if self._homogeneous:
                params, mcfg = self.drafters[0]
                logits = self._buffer_forward(
                    params, mcfg, jnp.asarray(bufs.reshape(r_n * k_n, t_n)))
                self.num_draft_forwards += 1
                sel = logits[jnp.arange(r_n * k_n),
                             jnp.asarray(np.repeat(pos, k_n))]
                p_all = probs_from_logits(sel, cfg.temps[0], cfg.top_k, n)
            else:
                cols = []
                for k in range(k_n):
                    params, mcfg = self.drafters[k]
                    logits = self._buffer_forward(
                        params, mcfg, jnp.asarray(bufs[:, k]))
                    self.num_draft_forwards += 1
                    sel = logits[jnp.arange(r_n), jnp.asarray(pos)]
                    cols.append(probs_from_logits(sel, cfg.temps[k],
                                                  cfg.top_k, n))
                p_all = jnp.stack(cols, axis=1).reshape(r_n * k_n, n)
            toks = V.draft_token_from_uniforms(
                log_u_all[:, j].reshape(r_n * k_n, n), p_all)
            tk = np.asarray(toks).reshape(r_n, k_n)  # 1 transfer / step
            self.num_draft_syncs += 1
            d_tokens[:, :, j] = tk
            for r in range(r_n):
                bufs[r, rows, p0s[r] + j] = tk[r]
            if need_probs:
                prob_steps.append(p_all)
        d_probs = None
        if need_probs:
            d_probs = jnp.stack(prob_steps).reshape(
                l_n, r_n, k_n, n).transpose(1, 2, 0, 3)
        return d_tokens, d_probs

    def _score_block(self, bufs: np.ndarray, p0s: np.ndarray) -> jax.Array:
        """ONE target forward over all R*K stacked draft buffers; gathers
        q(. | X^(k)_{1:j}, c) at each request's L+1 scoring positions.
        Returns (R, K, L+1, N)."""
        cfg = self.cfg
        r_n, k_n, t_n = bufs.shape
        l_n = cfg.draft_len
        logits = self._buffer_forward(
            self.t_params, self.t_cfg, jnp.asarray(bufs.reshape(r_n * k_n,
                                                                t_n)))
        self.num_target_forwards += 1
        pos = np.stack([np.arange(p0 - 1, p0 + l_n) for p0 in p0s])
        rowpos = np.repeat(pos, k_n, axis=0)                # (R*K, L+1)
        sel = logits[jnp.arange(r_n * k_n)[:, None], jnp.asarray(rowpos)]
        q = probs_from_logits(sel, cfg.target_temp, cfg.top_k, self.vocab)
        return q.reshape(r_n, k_n, l_n + 1, self.vocab)

    # -- speculative blocks -------------------------------------------------
    def gen_blocks(self, subs: Sequence[jax.Array],
                   prefixes: Sequence[np.ndarray],
                   buf_len: int) -> list:
        """Advance R requests by one speculative block each: one batched
        draft loop, ONE target forward, one fused verification per
        request.  Per-request RNG streams (``subs``) are independent, so
        the result is bit-identical to R sequential ``gen_block`` calls.
        Returns a list of BlockOutcome."""
        cfg = self.cfg
        r_n, k_n = len(prefixes), cfg.num_drafts
        rand = [self._block_randomness(s) for s in subs]
        log_u_all = jnp.stack([lu for lu, _ in rand])    # (R, L+1, K, N)
        p0s = np.asarray([len(p) for p in prefixes])
        bufs = np.zeros((r_n, k_n, buf_len), np.int32)
        for r, pre in enumerate(prefixes):
            bufs[r, :, :len(pre)] = pre
        d_tokens, d_probs = self._draft_block(log_u_all, bufs, p0s)
        q = self._score_block(bufs, p0s)
        outs = []
        # Verification dispatches per request (R jitted calls, R
        # transfers per round).  A vmapped (R, ...) block_verify with one
        # device_get would cut this to a single transfer; it is kept
        # per-request for now so the batched path stays trivially
        # bit-identical to the sequential one.
        for r in range(r_n):
            hb = run_block_verify(
                log_u_all[r], d_tokens[r],
                None if d_probs is None else d_probs[r], q[r], rand[r][1],
                strategy=cfg.strategy, backend=cfg.verifier_backend,
                interpret=cfg.pallas_interpret)
            outs.append(BlockOutcome(new_tokens=hb.new_tokens,
                                     accepted=hb.num_accepted,
                                     verify_syncs=hb.host_syncs,
                                     active=hb.active))
        return outs

    def gen_block(self, key: jax.Array, prefix: np.ndarray,
                  buf_len: int) -> BlockOutcome:
        """Single-request speculative block (the R=1 case of gen_blocks)."""
        return self.gen_blocks([key], [np.asarray(prefix, np.int32)],
                               buf_len)[0]

    def _gen_block(self, key: jax.Array, prefix: np.ndarray, buf_len: int):
        """Back-compat shim for the pre-refactor private API."""
        out = self.gen_block(key, prefix, buf_len)
        return out.new_tokens, out.accepted

    # -- public API ---------------------------------------------------------
    def generate(self, key: jax.Array, prompt: np.ndarray,
                 max_new: Optional[int] = None) -> GenerationStats:
        max_new = max_new or self.cfg.max_new_tokens
        prefix = np.asarray(prompt, np.int32)
        buf_len = len(prefix) + max_new + self.cfg.draft_len + 2
        blocks = 0
        accepted = 0
        syncs = 0
        n0 = len(prefix)
        while len(prefix) - n0 < max_new:
            key, sub = jax.random.split(key)
            out = self.gen_block(sub, prefix, buf_len)
            prefix = np.concatenate(
                [prefix, np.asarray(out.new_tokens, np.int32)])
            blocks += 1
            accepted += out.accepted
            syncs += out.verify_syncs
        return GenerationStats(output=prefix[n0:n0 + max_new], blocks=blocks,
                               accepted_drafts=accepted, host_syncs=syncs)

    def serve(self, key: jax.Array, prompts: Sequence[np.ndarray],
              max_new: Optional[int] = None) -> list:
        """Batched serving: each request advances one speculative block per
        round; model calls batch over live requests x drafts."""
        results = []
        for i, prompt in enumerate(prompts):
            results.append(self.generate(jax.random.fold_in(key, i),
                                         prompt, max_new))
        return results


def autoregressive_reference(key: jax.Array, target: tuple,
                             prompt: np.ndarray, max_new: int,
                             temp: float = 1.0, top_k: int = 50,
                             use_gumbel_trace: bool = True) -> np.ndarray:
    """Plain autoregressive sampling from the target — the distribution
    speculative decoding must preserve.  With ``use_gumbel_trace`` the
    sampler uses the same per-step Gumbel-race construction as GLS with
    K=1 so sequence-level equality (not just distributional) can be
    checked under shared randomness."""
    params, mcfg = target
    prefix = np.asarray(prompt, np.int32)
    buf_len = len(prefix) + max_new + 1
    fwd = jax.jit(lambda p, t: forward(p, mcfg, {"tokens": t}, remat=False))
    buf = np.zeros((1, buf_len), np.int32)
    buf[0, :len(prefix)] = prefix
    out = []
    n = len(prefix)
    for i in range(max_new):
        key, sub = jax.random.split(key)
        logits = fwd(params, jnp.asarray(buf))[0, n - 1 + i]
        probs = probs_from_logits(logits, temp, top_k, mcfg.vocab_size)
        if use_gumbel_trace:
            log_u = jnp.log(jax.random.uniform(
                sub, (mcfg.vocab_size,),
                minval=np.finfo(np.float32).tiny, maxval=1.0))
            tok = int(V.gumbel_race_argmin(log_u, probs))
        else:
            tok = int(jax.random.categorical(
                sub, jnp.log(jnp.maximum(probs, 1e-30))))
        out.append(tok)
        buf[0, n + i] = tok
    return np.asarray(out, np.int32)
