"""Multi-draft speculative decoding engine (paper Sec. 4, Algorithm 2).

Design notes
------------
* Drafts and target are coupled through *common random numbers*: one block
  draws uniforms U[(L+1), K, N]; draft k samples its j-th token by the
  Gumbel race on U[j, k] and the GLS verifier races the target
  distributions on the very same sheet — this is what makes acceptance
  high AND the output conditionally drafter-invariant (Def. 1).
* Model evaluation uses fixed-size token buffers so jitted forwards
  compile once per (batch, buffer) shape: causal attention makes trailing
  garbage harmless.  The target scores all K draft continuations in one
  batched forward (the K dimension rides in the batch), matching how a
  TPU serving deployment folds drafts into the batch (DESIGN.md §3).
* Strategies: "gls" (Alg. 2), "gls_strong" (App. B), "specinfer",
  "spectr", "single" (Leviathan), "daliri" (single-draft coupling).
  K heterogeneous drafters with per-drafter temperatures are supported
  for the paper's diverse-drafts experiment (Table 2/4).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import forward
from repro.models.config import ModelConfig
from repro.specdec import verify as V

STRATEGIES = ("gls", "gls_strong", "specinfer", "spectr", "single", "daliri")


@dataclasses.dataclass(frozen=True)
class SpecDecConfig:
    num_drafts: int = 8           # K
    draft_len: int = 4            # L
    strategy: str = "gls"
    target_temp: float = 1.0
    draft_temps: Optional[tuple] = None   # per-drafter; default all 1.0
    top_k: int = 50               # paper uses top-K 50 sampling
    max_new_tokens: int = 64

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}")

    @property
    def temps(self) -> tuple:
        if self.draft_temps is not None:
            assert len(self.draft_temps) == self.num_drafts
            return tuple(self.draft_temps)
        return (1.0,) * self.num_drafts


@dataclasses.dataclass
class GenerationStats:
    output: np.ndarray            # accepted token ids
    blocks: int                   # target model calls
    accepted_drafts: int          # accepted DRAFT tokens (excl. bonus)

    @property
    def block_efficiency(self) -> float:
        """Tokens emitted per target call (paper's BE metric)."""
        return len(self.output) / max(self.blocks, 1)


def probs_from_logits(logits: jax.Array, temp: float, top_k: int,
                      vocab_size: int) -> jax.Array:
    """Temperature + top-k filtered probabilities over the TRUE vocab."""
    logits = logits[..., :vocab_size].astype(jnp.float32)
    if temp <= 0:
        # Greedy as a limiting case: delta on the argmax.
        return jax.nn.one_hot(jnp.argmax(logits, -1), vocab_size)
    logits = logits / temp
    if top_k and top_k < vocab_size:
        kth = jnp.sort(logits, axis=-1)[..., -top_k][..., None]
        logits = jnp.where(logits >= kth, logits, -jnp.inf)
    return jax.nn.softmax(logits, axis=-1)


class SpecDecEngine:
    """Speculative decoding over one target and K (possibly distinct)
    drafters sharing the target's vocabulary."""

    def __init__(self, target: tuple, drafters: Sequence[tuple],
                 cfg: SpecDecConfig):
        self.t_params, self.t_cfg = target
        self.drafters = list(drafters)
        if len(self.drafters) == 1 and cfg.num_drafts > 1:
            self.drafters = self.drafters * cfg.num_drafts
        assert len(self.drafters) == cfg.num_drafts
        self.cfg = cfg
        self.vocab = self.t_cfg.vocab_size
        self._fwd_cache = {}

    # -- jitted, shape-stable model calls ---------------------------------
    def _buffer_forward(self, params, mcfg: ModelConfig, tokens: jax.Array):
        key = (id(params), tokens.shape)
        if key not in self._fwd_cache:
            def f(p, t):
                return forward(p, mcfg, {"tokens": t}, remat=False)
            self._fwd_cache[key] = jax.jit(f)
        return self._fwd_cache[key](params, tokens)

    def _target_probs_at(self, tokens_buf: jax.Array, positions: np.ndarray):
        """tokens_buf: (K, T) buffers; returns q at `positions` (per row):
        (K, len(positions), N)."""
        logits = self._buffer_forward(self.t_params, self.t_cfg, tokens_buf)
        sel = logits[:, positions]  # same positions for all rows
        return probs_from_logits(sel, self.cfg.target_temp, self.cfg.top_k,
                                 self.vocab)

    def _draft_probs_at(self, k: int, tokens_buf: jax.Array, position: int):
        params, mcfg = self.drafters[k]
        logits = self._buffer_forward(params, mcfg, tokens_buf)
        return probs_from_logits(logits[:, position], self.cfg.temps[k],
                                 self.cfg.top_k, self.vocab)

    # -- one speculative block --------------------------------------------
    def _gen_block(self, key: jax.Array, prefix: np.ndarray, buf_len: int):
        """Generate K drafts of length L from `prefix`, verify, and return
        (new_tokens list, accepted_draft_count)."""
        cfg = self.cfg
        K, Lr = cfg.num_drafts, cfg.draft_len
        N = self.vocab
        k_unif, k_strat = jax.random.split(key)
        # Shared log-uniforms for the whole block: (L+1, K, N).
        log_u = jnp.log(jax.random.uniform(
            k_unif, (Lr + 1, K, N),
            minval=np.finfo(np.float32).tiny, maxval=1.0))

        p0 = len(prefix)
        # --- draft generation (autoregressive, Gumbel race per drafter) ---
        draft_tokens = np.zeros((K, Lr), np.int32)
        draft_probs = np.zeros((K, Lr, N), np.float32)
        bufs = np.zeros((K, buf_len), np.int32)
        bufs[:, :p0] = prefix
        same_drafter = all(d is self.drafters[0] for d in self.drafters)
        uniform_temp = len(set(cfg.temps)) == 1
        for j in range(Lr):
            pos = p0 + j - 1
            if same_drafter and uniform_temp:
                p_all = self._draft_probs_at(0, jnp.asarray(bufs), pos)  # (K,N)
            else:
                p_all = jnp.stack([
                    self._draft_probs_at(k, jnp.asarray(bufs[k:k + 1]), pos)[0]
                    for k in range(K)])
            toks = V.draft_token_from_uniforms(log_u[j], p_all)  # (K,)
            draft_tokens[:, j] = np.asarray(toks)
            draft_probs[:, j] = np.asarray(p_all)
            bufs[np.arange(K), p0 + j] = draft_tokens[:, j]

        # --- target scoring: one batched forward over the K buffers -------
        positions = np.arange(p0 - 1, p0 + Lr)  # q^(1..L+1)
        q_all = np.asarray(self._target_probs_at(jnp.asarray(bufs), positions))
        # q_all: (K, L+1, N); q_all[k, j] = q(. | X^(k)_{1:j}, c)

        # --- verification loop (Algorithm 2) -------------------------------
        out_tokens = []
        active = jnp.ones((K,), bool)
        accepted_drafts = 0
        strat_keys = jax.random.split(k_strat, Lr + 1)
        for j in range(Lr):
            q_j = jnp.asarray(q_all[:, j])      # (K, N)
            d_j = jnp.asarray(draft_tokens[:, j])
            if cfg.strategy == "gls":
                res = V.gls_verify(log_u[j], d_j, q_j, active)
            elif cfg.strategy == "gls_strong":
                res = V.gls_verify_strong(log_u[j], d_j, q_j, active)
            elif cfg.strategy == "specinfer":
                res = V.specinfer_verify(strat_keys[j],
                                         jnp.asarray(draft_probs[:, j]),
                                         d_j, q_j, active)
            elif cfg.strategy == "spectr":
                res = V.spectr_verify(strat_keys[j],
                                      jnp.asarray(draft_probs[:, j]),
                                      d_j, q_j, active)
            elif cfg.strategy == "single":
                res = V.single_draft_verify(strat_keys[j],
                                            jnp.asarray(draft_probs[0, j]),
                                            d_j[0], q_j[0])
            elif cfg.strategy == "daliri":
                res = V.daliri_verify(log_u[j, 0], d_j[0], q_j[0])
            out_tokens.append(int(res.token))
            if not bool(res.accepted):
                return out_tokens, accepted_drafts
            accepted_drafts += 1
            active = res.new_active
            if cfg.strategy in ("single", "daliri"):
                # Single-draft: continue only along draft 0's path.
                active = jnp.zeros((K,), bool).at[0].set(True)

        # All L draft tokens accepted: emit the bonus token Y_{L+1}.
        q_last = jnp.asarray(q_all[:, Lr])
        if cfg.strategy in ("gls", "gls_strong"):
            act = active if cfg.strategy == "gls" else jnp.ones((K,), bool)
            score = jnp.log(-log_u[Lr]) - jnp.log(jnp.maximum(q_last, 1e-30))
            score = jnp.where(q_last > 0, score, jnp.inf)
            score = jnp.where(act[:, None], score, jnp.inf)
            bonus = int(jnp.argmin(score) % N)
        else:
            k_idx = int(jnp.argmax(active))
            bonus = int(jax.random.categorical(
                strat_keys[Lr], jnp.log(jnp.maximum(q_last[k_idx], 1e-30))))
        out_tokens.append(bonus)
        return out_tokens, accepted_drafts

    # -- public API ---------------------------------------------------------
    def generate(self, key: jax.Array, prompt: np.ndarray,
                 max_new: Optional[int] = None) -> GenerationStats:
        max_new = max_new or self.cfg.max_new_tokens
        prefix = np.asarray(prompt, np.int32)
        buf_len = len(prefix) + max_new + self.cfg.draft_len + 2
        blocks = 0
        accepted = 0
        n0 = len(prefix)
        while len(prefix) - n0 < max_new:
            key, sub = jax.random.split(key)
            new, acc = self._gen_block(sub, prefix, buf_len)
            prefix = np.concatenate([prefix, np.asarray(new, np.int32)])
            blocks += 1
            accepted += acc
        return GenerationStats(output=prefix[n0:n0 + max_new], blocks=blocks,
                               accepted_drafts=accepted)

    def serve(self, key: jax.Array, prompts: Sequence[np.ndarray],
              max_new: Optional[int] = None) -> list:
        """Batched serving: each request advances one speculative block per
        round; model calls batch over live requests x drafts."""
        results = []
        for i, prompt in enumerate(prompts):
            results.append(self.generate(jax.random.fold_in(key, i),
                                         prompt, max_new))
        return results


def autoregressive_reference(key: jax.Array, target: tuple,
                             prompt: np.ndarray, max_new: int,
                             temp: float = 1.0, top_k: int = 50,
                             use_gumbel_trace: bool = True) -> np.ndarray:
    """Plain autoregressive sampling from the target — the distribution
    speculative decoding must preserve.  With ``use_gumbel_trace`` the
    sampler uses the same per-step Gumbel-race construction as GLS with
    K=1 so sequence-level equality (not just distributional) can be
    checked under shared randomness."""
    params, mcfg = target
    prefix = np.asarray(prompt, np.int32)
    buf_len = len(prefix) + max_new + 1
    fwd = jax.jit(lambda p, t: forward(p, mcfg, {"tokens": t}, remat=False))
    buf = np.zeros((1, buf_len), np.int32)
    buf[0, :len(prefix)] = prefix
    out = []
    n = len(prefix)
    for i in range(max_new):
        key, sub = jax.random.split(key)
        logits = fwd(params, jnp.asarray(buf))[0, n - 1 + i]
        probs = probs_from_logits(logits, temp, top_k, mcfg.vocab_size)
        if use_gumbel_trace:
            log_u = jnp.log(jax.random.uniform(
                sub, (mcfg.vocab_size,),
                minval=np.finfo(np.float32).tiny, maxval=1.0))
            tok = int(V.gumbel_race_argmin(log_u, probs))
        else:
            tok = int(jax.random.categorical(
                sub, jnp.log(jnp.maximum(probs, 1e-30))))
        out.append(tok)
        buf[0, n + i] = tok
    return np.asarray(out, np.int32)
