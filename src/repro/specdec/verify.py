"""Token-level verification strategies for multi-draft speculative decoding.

All verifiers share the same contract, operating on ONE decoding step:

  verify(key, draft_probs (K,N), target_probs (K,N), draft_tokens (K,),
         active (K,) bool) -> StepResult(token, accepted, new_active)

``target_probs[k]`` is the target distribution conditioned on draft k's
prefix (they coincide while drafts agree).  ``active`` marks drafts whose
prefix still matches the accepted output.

Implemented strategies:
  * ``gls_verify``            — the paper's Algorithm 2 (conditionally
                                drafter-invariant; min over ACTIVE drafts).
  * ``gls_verify_strong``     — App. B variant (min over ALL K drafts;
                                strong drafter invariance, lower acceptance).
  * ``specinfer_verify``      — SpecInfer recursive rejection sampling.
  * ``spectr_verify``         — SpecTr-style k-sequential OT verification.
  * ``single_draft_verify``   — Leviathan et al. (K=1 rejection sampling).
  * ``daliri_verify``         — Daliri et al. single-draft Gumbel coupling.

Everything is jit-able; randomness is explicit via keys.  GLS variants use
*shared* uniforms (common random numbers) — the same key must be used by
the drafter when sampling its tokens for the coupling to take effect
(see engine.py).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

_TINY = 1e-30


class StepResult(NamedTuple):
    token: jax.Array        # int32 — accepted (or resampled residual) token
    accepted: jax.Array     # bool — True if token came from some draft
    new_active: jax.Array   # (K,) bool — drafts still viable AFTER this step


def gumbel_race_argmin(log_u: jax.Array, probs: jax.Array) -> jax.Array:
    """argmin_i  -ln(U_i) / p_i  computed stably in log space.

    log_u: (..., N) log of shared uniforms; probs: (..., N).
    """
    log_s = jnp.log(-log_u)  # log(-ln U) = log of Exp(1) sample
    score = log_s - jnp.log(jnp.maximum(probs, _TINY))
    score = jnp.where(probs > 0, score, jnp.inf)
    return jnp.argmin(score, axis=-1).astype(jnp.int32)


def draft_token_from_uniforms(log_u: jax.Array, draft_probs: jax.Array):
    """Gumbel-max draft sampling from the SAME uniforms used at verify."""
    return gumbel_race_argmin(log_u, draft_probs)


# ---------------------------------------------------------------------------
# GLS (the paper's scheme)
# ---------------------------------------------------------------------------


def gls_verify(log_u: jax.Array, draft_tokens: jax.Array,
               target_probs: jax.Array, active: jax.Array) -> StepResult:
    """Algorithm 2, one step.  log_u: (K, N) shared log-uniforms;
    target_probs: (K, N) — q(. | draft k's prefix); rows for inactive
    drafts are ignored via +inf race times.
    """
    log_s = jnp.log(-log_u)  # (K, N)
    score = log_s - jnp.log(jnp.maximum(target_probs, _TINY))
    score = jnp.where(target_probs > 0, score, jnp.inf)
    score = jnp.where(active[:, None], score, jnp.inf)
    flat = jnp.argmin(score)
    token = (flat % score.shape[1]).astype(jnp.int32)
    new_active = active & (draft_tokens == token)
    accepted = jnp.any(new_active)
    return StepResult(token=token, accepted=accepted, new_active=new_active)


def gls_verify_strong(log_u: jax.Array, draft_tokens: jax.Array,
                      target_probs: jax.Array, active: jax.Array) -> StepResult:
    """App. B: min over ALL drafts regardless of viability -> strong
    drafter invariance, at an acceptance cost (Prop. 6)."""
    log_s = jnp.log(-log_u)
    score = log_s - jnp.log(jnp.maximum(target_probs, _TINY))
    score = jnp.where(target_probs > 0, score, jnp.inf)
    flat = jnp.argmin(score)
    token = (flat % score.shape[1]).astype(jnp.int32)
    new_active = active & (draft_tokens == token)
    accepted = jnp.any(new_active)
    return StepResult(token=token, accepted=accepted, new_active=new_active)


# ---------------------------------------------------------------------------
# SpecInfer (recursive rejection sampling)
# ---------------------------------------------------------------------------


def specinfer_verify(key: jax.Array, draft_probs: jax.Array,
                     draft_tokens: jax.Array, target_probs: jax.Array,
                     active: jax.Array) -> StepResult:
    """SpecInfer: sequentially try each active draft token with standard
    rejection (u < q(x)/p(x)); on rejection, update the residual
    q <- norm(max(q - p, 0)) and move to the next draft.  If all fail,
    sample from the final residual.

    Note the order dependence — the paper's Table 2 exploits exactly this.
    """
    k, n = draft_probs.shape
    keys = jax.random.split(key, k + 1)

    def body(carry, idx):
        q, done, token = carry
        x = draft_tokens[idx]
        px = jnp.maximum(draft_probs[idx, x], _TINY)
        qx = q[x]
        u = jax.random.uniform(keys[idx])
        ok = active[idx] & (u < qx / px) & (~done)
        token = jnp.where(ok, x, token)
        done = done | ok
        # Residual update only if this draft was tried and rejected.
        tried = active[idx] & (~done)
        resid = jnp.maximum(q - draft_probs[idx], 0.0)
        rsum = jnp.sum(resid)
        resid = jnp.where(rsum > _TINY, resid / rsum, q)
        q = jnp.where(tried, resid, q)
        return (q, done, token), ok

    (q, done, token), oks = jax.lax.scan(
        body, (target_probs[0], False, jnp.int32(0)), jnp.arange(k))
    resid_tok = jax.random.categorical(keys[k], jnp.log(jnp.maximum(q, _TINY)))
    token = jnp.where(done, token, resid_tok.astype(jnp.int32))
    accepted = done
    # A draft survives only if its token was THE accepted one and it was
    # previously active.
    new_active = active & (draft_tokens == token) & accepted
    return StepResult(token=token, accepted=accepted, new_active=new_active)


# ---------------------------------------------------------------------------
# SpecTr (k-sequential draft selection; i.i.d. proposals)
# ---------------------------------------------------------------------------


def spectr_verify(key: jax.Array, draft_probs: jax.Array,
                  draft_tokens: jax.Array, target_probs: jax.Array,
                  active: jax.Array) -> StepResult:
    """SpecTr K-SEQ (Sun et al. 2023), specialized to i.i.d. proposals:
    try the J active drafts in order, accepting X_i with probability
        b(X_i) = min(1, q(X_i) / (J * p(X_i))),
    and on total rejection sample the deflated residual

        resid(x) ∝ q(x) - p(x) b(x) (1 - (1-ā)^J)/ā,   ā = Σ_x p(x) b(x),

    which makes the output marginal exactly q (the 1/J deflation is what
    keeps the residual non-negative).
    """
    k, n = draft_probs.shape
    keys = jax.random.split(key, k + 1)
    p = draft_probs[0]
    q = target_probs[0]
    j_act = jnp.maximum(jnp.sum(active.astype(jnp.float32)), 1.0)

    b = jnp.minimum(1.0, q / jnp.maximum(j_act * p, _TINY))
    b = jnp.where(p > 0, b, 0.0)
    abar = jnp.sum(p * b)

    def body(carry, idx):
        done, token = carry
        x = draft_tokens[idx]
        u = jax.random.uniform(keys[idx])
        ok = active[idx] & (u < b[x]) & (~done)
        token = jnp.where(ok, x, token)
        return (done | ok, token), ok

    (done, token), _ = jax.lax.scan(body, (False, jnp.int32(0)),
                                    jnp.arange(k))
    scale = jnp.where(abar > _TINY,
                      (1.0 - (1.0 - abar) ** j_act) / jnp.maximum(abar, _TINY),
                      j_act)
    resid = jnp.maximum(q - p * b * scale, 0.0)
    rsum = jnp.sum(resid)
    resid = jnp.where(rsum > _TINY, resid / rsum, q)
    resid_tok = jax.random.categorical(keys[k], jnp.log(jnp.maximum(resid, _TINY)))
    token = jnp.where(done, token, resid_tok.astype(jnp.int32))
    new_active = active & (draft_tokens == token) & done
    return StepResult(token=token, accepted=done, new_active=new_active)


# ---------------------------------------------------------------------------
# Single-draft baselines
# ---------------------------------------------------------------------------


def single_draft_verify(key: jax.Array, draft_probs: jax.Array,
                        draft_token: jax.Array,
                        target_probs: jax.Array) -> StepResult:
    """Leviathan et al.: accept w.p. min(1, q(x)/p(x)); else sample the
    normalized residual max(q-p, 0)."""
    kk1, kk2 = jax.random.split(key)
    x = draft_token
    px = jnp.maximum(draft_probs[x], _TINY)
    ok = jax.random.uniform(kk1) < jnp.minimum(1.0, target_probs[x] / px)
    resid = jnp.maximum(target_probs - draft_probs, 0.0)
    rsum = jnp.sum(resid)
    resid = jnp.where(rsum > _TINY, resid / rsum, target_probs)
    resid_tok = jax.random.categorical(kk2, jnp.log(jnp.maximum(resid, _TINY)))
    token = jnp.where(ok, x, resid_tok.astype(jnp.int32))
    return StepResult(token=token, accepted=ok,
                      new_active=ok[None])


def daliri_verify(log_u: jax.Array, draft_token: jax.Array,
                  target_probs: jax.Array) -> StepResult:
    """Daliri et al. single-draft Gumbel coupling: target races on the
    SAME uniforms the drafter used (K=1 GLS)."""
    token = gumbel_race_argmin(log_u, target_probs)
    ok = token == draft_token
    return StepResult(token=token, accepted=ok, new_active=ok[None])
