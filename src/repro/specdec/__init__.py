"""Multi-draft speculative decoding (the paper's Sec. 4 application)."""

from repro.specdec.engine import (
    GenerationStats,
    SpecDecConfig,
    SpecDecEngine,
    autoregressive_reference,
    probs_from_logits,
)
from repro.specdec.engine_cached import CachedSpecDecEngine
from repro.specdec.scheduler import SpecDecServer
from repro.specdec.verify import (
    StepResult,
    daliri_verify,
    draft_token_from_uniforms,
    gls_verify,
    gls_verify_strong,
    gumbel_race_argmin,
    single_draft_verify,
    specinfer_verify,
    spectr_verify,
)

__all__ = [
    "CachedSpecDecEngine",
    "GenerationStats",
    "SpecDecServer",
    "SpecDecConfig",
    "SpecDecEngine",
    "StepResult",
    "autoregressive_reference",
    "daliri_verify",
    "draft_token_from_uniforms",
    "gls_verify",
    "gls_verify_strong",
    "gumbel_race_argmin",
    "probs_from_logits",
    "single_draft_verify",
    "specinfer_verify",
    "spectr_verify",
]
