"""Multi-draft speculative decoding (the paper's Sec. 4 application)."""

from repro.specdec.block_verify import (
    BACKENDS,
    BlockVerifyResult,
    HostBlockResult,
    RACE_STRATEGIES,
    RS_STRATEGIES,
    block_verify,
    block_verify_batched,
    legacy_block_verify,
    run_block_verify,
)
from repro.specdec.engine import (
    STRATEGIES,
    BlockOutcome,
    GenerationStats,
    SpecDecConfig,
    SpecDecEngine,
    autoregressive_reference,
    probs_from_logits,
)
from repro.specdec.engine_cached import CachedSpecDecEngine
from repro.specdec.scheduler import SpecDecServer
from repro.specdec.verify import (
    StepResult,
    daliri_verify,
    draft_token_from_uniforms,
    gls_verify,
    gls_verify_strong,
    gumbel_race_argmin,
    single_draft_verify,
    specinfer_verify,
    spectr_verify,
)

__all__ = [
    "BACKENDS",
    "BlockOutcome",
    "BlockVerifyResult",
    "CachedSpecDecEngine",
    "GenerationStats",
    "HostBlockResult",
    "RACE_STRATEGIES",
    "RS_STRATEGIES",
    "STRATEGIES",
    "SpecDecServer",
    "SpecDecConfig",
    "SpecDecEngine",
    "StepResult",
    "autoregressive_reference",
    "block_verify",
    "block_verify_batched",
    "daliri_verify",
    "draft_token_from_uniforms",
    "gls_verify",
    "gls_verify_strong",
    "gumbel_race_argmin",
    "legacy_block_verify",
    "probs_from_logits",
    "run_block_verify",
    "single_draft_verify",
    "specinfer_verify",
    "spectr_verify",
]
