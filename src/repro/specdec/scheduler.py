"""Batched request scheduler for speculative-decoding serving.

A minimal continuous-batching-lite scheduler: requests join a queue, up
to ``max_batch`` live requests advance one speculative block per round
(each with its own RNG stream), finished requests leave and queued ones
join at round boundaries.  Tracks the serving metrics a deployment would
export: time-to-first-block, tokens/s, block efficiency, acceptance
rate, host-sync counts.

All execution modes share one policy (admission order, RNG derivation,
buffer sizing), so their outputs are bit-identical:

  * sequential (``batched=False``): one engine block per live request per
    round — R target forwards per round;
  * batched (``batched=True``): all live requests' draft buffers stack
    into (R*K, T) model calls via ``SpecDecEngine.gen_blocks`` — ONE
    target forward per round regardless of R;
  * kv (``cache_mode="kv"``): a ``CachedSpecDecEngine`` keeps every live
    request's target and drafter caches resident in a slot-based cache
    pool across rounds (admit on first block, release on completion) —
    one drafter decode sweep plus ONE stacked ``verify_step`` per round,
    no per-block re-prefill (DESIGN.md §7).  The first two modes
    re-score the whole prefix every block, O(T^2) per request;
  * kv_fused (``cache_mode="kv_fused"``): same engine and pool, but the
    whole round — drafter sweep, stacked verify, Algorithm-2
    verification, rollback, catch-up — runs as ONE jitted device
    program (DESIGN.md §8): no per-draft-step host transfer
    (``draft_syncs == 0``) and exactly one host sync per round.

RNG streams are derived per request as
``fold_in(fold_in(key, uid), blocks)`` — NESTED folds, because the
flat ``fold_in(key, uid * 1000 + blocks)`` encoding collides across
requests once a request reaches 1000 blocks (uid 1 block 1000 == uid 2
block 0), silently coupling two requests' draws.  ``run()`` feeds the
SAME key to every round, so a request's stream depends only on
(uid, blocks), never on WHICH round a block lands in — that round-
independence is what lets kv_fused defer a newly admitted request's
first block to the round after its overlapped prefill (DESIGN.md §9)
while staying bit-identical to the modes that run it immediately.
(The former per-round ``fold_in(key, round_idx)`` would have tied
every block's randomness to the admission policy.)

Admission (``admission="bucketed"``, the default) drains the queue
into the engine's bucketed batched-prefill waves; under kv_fused the
wave's prefills are dispatched while the current round runs and the
admitted requests join the live set next round.  ``per_request`` keeps
the one-prefill-pair-per-request reference path (the TTFT baseline in
the bursty-admission bench).

Buffer lengths grow monotonically to the largest live requirement
(queued requests count from their admission round), so a request's
compiled shapes — and therefore its sampled tokens — never depend on
which mode ran it (trailing-buffer content does not affect causal
logits, but buffer LENGTH changes compiled reduction shapes, so it is
pinned scheduler-side).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import numpy as np

from repro.specdec.engine import SpecDecConfig, SpecDecEngine


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new: int
    # runtime state
    output: list = dataclasses.field(default_factory=list)
    blocks: int = 0
    accepted: int = 0
    t_submit: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def done(self) -> bool:
        return len(self.output) >= self.max_new

    @property
    def block_efficiency(self) -> float:
        return len(self.output) / max(self.blocks, 1)

    @property
    def ttft_ms(self) -> Optional[float]:
        """Time-to-first-token: submission to first emitted tokens."""
        if self.t_first is None:
            return None
        return (self.t_first - self.t_submit) * 1e3


@dataclasses.dataclass
class ServerMetrics:
    completed: int = 0
    total_tokens: int = 0
    total_blocks: int = 0
    rounds: int = 0
    target_forwards: int = 0
    host_syncs: int = 0          # verification device->host transfers
    draft_syncs: int = 0         # draft-token materialization transfers
    # Wall time is accumulated per ``step()`` call, so ``tokens_per_s``
    # is meaningful whether callers drive ``run()`` or ``step()``
    # directly (``run()`` previously set it; direct ``step()`` callers
    # divided by the 1e-9 floor and reported nonsense).
    wall_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.total_tokens / max(self.wall_s, 1e-9)

    @property
    def mean_block_efficiency(self) -> float:
        return self.total_tokens / max(self.total_blocks, 1)


CACHE_MODES = ("reprefill", "kv", "kv_fused")
ADMISSION_MODES = ("bucketed", "per_request")


class SpecDecServer:
    """Round-robin block scheduler over a shared engine.

    ``cache_mode="reprefill"`` drives a reference ``SpecDecEngine``
    (stateless; full-prefix re-score per block, sequential or batched);
    ``cache_mode="kv"`` drives a ``CachedSpecDecEngine`` whose cache
    pool must have at least ``max_batch`` slots — requests are admitted
    to a slot at their first block and released on completion, and every
    round is one batched arena step (``batched`` is implied);
    ``cache_mode="kv_fused"`` is the same serving policy with the round
    executed as one fused device program (DESIGN.md §8).

    ``admission`` picks the cached-engine prefill path: "bucketed"
    (default — batched bucketed waves straight into pool slots,
    overlapped with the running round under kv_fused, DESIGN.md §9) or
    "per_request" (the reference path; also the TTFT baseline in the
    bursty-admission bench).  The policy is passed through to the
    engine per call, never written onto it.
    """

    def __init__(self, engine, max_batch: int = 8,
                 batched: bool = False, cache_mode: str = "reprefill",
                 admission: str = "bucketed"):
        if cache_mode not in CACHE_MODES:
            raise ValueError(f"unknown cache_mode {cache_mode!r}")
        if admission not in ADMISSION_MODES:
            raise ValueError(f"unknown admission mode {admission!r}")
        if cache_mode in ("kv", "kv_fused"):
            if not hasattr(engine, "admit"):
                raise TypeError(
                    f"cache_mode={cache_mode!r} needs a CachedSpecDecEngine")
            if engine.pool_slots < max_batch:
                raise ValueError(
                    f"engine pool has {engine.pool_slots} slots < "
                    f"max_batch={max_batch}")
        self.engine = engine
        self.max_batch = max_batch
        self.batched = batched
        self.cache_mode = cache_mode
        self.admission = admission
        self.queue: deque = deque()
        self.live: list = []
        self._uid = 0
        self._buf_len = 0
        self.metrics = ServerMetrics()

    def submit(self, prompt: np.ndarray, max_new: int = 32) -> int:
        self._uid += 1
        req = Request(uid=self._uid, prompt=np.asarray(prompt, np.int32),
                      max_new=max_new, t_submit=time.time())
        self.queue.append(req)
        return req.uid

    def _admit(self) -> list:
        """Move queued requests into the live set (up to ``max_batch``);
        returns the newly admitted requests."""
        newly = []
        while self.queue and len(self.live) < self.max_batch:
            req = self.queue.popleft()
            self.live.append(req)
            newly.append(req)
        return newly

    def _required_buf(self, req: Request) -> int:
        return len(req.prompt) + req.max_new + self.engine.cfg.draft_len + 2

    def step(self, key: jax.Array) -> list:
        """Advance every live request by one speculative block.  Returns
        requests that finished this round.

        Under kv_fused with bucketed admission, requests admitted THIS
        step only prefill (overlapped with the round advancing the
        previously admitted requests, DESIGN.md §9) and start emitting
        tokens next step.  Round-alignment differences between modes
        are token-invisible because per-request randomness depends only
        on (uid, blocks) — callers comparing admission policies must
        pass the same ``key`` every step, as ``run()`` does."""
        t0 = time.perf_counter()
        newly = self._admit()
        if not self.live:
            return []
        self._buf_len = max([self._buf_len]
                            + [self._required_buf(r) for r in self.live])
        overlap = (self.cache_mode == "kv_fused"
                   and self.admission == "bucketed")
        new_ids = {id(r) for r in newly}
        advancing = [r for r in self.live if id(r) not in new_ids] \
            if overlap else self.live
        # Nested folds: a flat uid * C + blocks encoding collides across
        # requests once blocks reaches C (see module docstring).
        subs = [jax.random.fold_in(jax.random.fold_in(key, r.uid), r.blocks)
                for r in advancing]
        fw0 = self.engine.num_target_forwards
        ds0 = getattr(self.engine, "num_draft_syncs", 0)
        if overlap:
            # The overlap path skips full-prefix assembly (the engine
            # serves from cached state) but still hands over each
            # request's last emitted token so the engine can enforce
            # the prefix-tail == pending contract loudly.
            tails = [int(r.output[-1]) if r.output else int(r.prompt[-1])
                     for r in advancing]
            outs = self.engine.round_with_admission(
                subs, [r.uid for r in advancing],
                [(r.uid, r.prompt) for r in newly], self._buf_len,
                tails=tails)
        else:
            prefixes = [np.concatenate([r.prompt,
                                        np.asarray(r.output, np.int32)])
                        for r in advancing]
            if self.cache_mode in ("kv", "kv_fused"):
                outs = self.engine.gen_blocks(
                    subs, prefixes, self._buf_len,
                    uids=[r.uid for r in advancing],
                    fused=self.cache_mode == "kv_fused",
                    admission=self.admission)
            elif self.batched:
                outs = self.engine.gen_blocks(subs, prefixes, self._buf_len)
            else:
                outs = [self.engine.gen_block(sub, prefix, self._buf_len)
                        for sub, prefix in zip(subs, prefixes)]
        if advancing:
            self.metrics.rounds += 1
        self.metrics.target_forwards += self.engine.num_target_forwards - fw0
        self.metrics.draft_syncs += (
            getattr(self.engine, "num_draft_syncs", 0) - ds0)

        finished = []
        for req, out in zip(advancing, outs):
            req.output.extend(out.new_tokens)
            req.blocks += 1
            req.accepted += out.accepted
            self.metrics.host_syncs += out.verify_syncs
            if req.t_first is None:
                req.t_first = time.time()
            if req.done:
                req.output = req.output[:req.max_new]
                req.t_done = time.time()
                finished.append(req)
        for req in finished:
            self.live.remove(req)
            if self.cache_mode in ("kv", "kv_fused"):
                self.engine.release(req.uid)
            self.metrics.completed += 1
            self.metrics.total_tokens += len(req.output)
            self.metrics.total_blocks += req.blocks
        self.metrics.wall_s += time.perf_counter() - t0
        return finished

    def run(self, key: jax.Array) -> list:
        """Drain the queue; returns all completed requests in finish order.
        Wall time accrues inside ``step()`` (shared with direct-step
        callers), so this loop adds no timing of its own.  The SAME key
        feeds every round — per-request streams are (uid, blocks)-keyed
        (module docstring), so which round a block lands in never
        changes its randomness."""
        done = []
        while self.queue or self.live:
            done.extend(self.step(key))
        return done
