"""Batched request scheduler for speculative-decoding serving.

A minimal continuous-batching-lite scheduler: requests join a queue, up
to ``max_batch`` live requests advance one speculative block per round
(each with its own RNG stream and engine state), finished requests leave
and queued ones join at round boundaries.  Tracks the serving metrics a
deployment would export: time-to-first-block, tokens/s, block efficiency,
acceptance rate.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import numpy as np

from repro.specdec.engine import SpecDecConfig, SpecDecEngine


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new: int
    # runtime state
    output: list = dataclasses.field(default_factory=list)
    blocks: int = 0
    accepted: int = 0
    t_submit: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def done(self) -> bool:
        return len(self.output) >= self.max_new

    @property
    def block_efficiency(self) -> float:
        return len(self.output) / max(self.blocks, 1)


@dataclasses.dataclass
class ServerMetrics:
    completed: int = 0
    total_tokens: int = 0
    total_blocks: int = 0
    wall_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.total_tokens / max(self.wall_s, 1e-9)

    @property
    def mean_block_efficiency(self) -> float:
        return self.total_tokens / max(self.total_blocks, 1)


class SpecDecServer:
    """Round-robin block scheduler over a shared SpecDecEngine."""

    def __init__(self, engine: SpecDecEngine, max_batch: int = 8):
        self.engine = engine
        self.max_batch = max_batch
        self.queue: deque = deque()
        self.live: list = []
        self._uid = 0
        self.metrics = ServerMetrics()

    def submit(self, prompt: np.ndarray, max_new: int = 32) -> int:
        self._uid += 1
        req = Request(uid=self._uid, prompt=np.asarray(prompt, np.int32),
                      max_new=max_new, t_submit=time.time())
        self.queue.append(req)
        return req.uid

    def _admit(self):
        while self.queue and len(self.live) < self.max_batch:
            self.live.append(self.queue.popleft())

    def step(self, key: jax.Array) -> list:
        """Advance every live request by one speculative block.  Returns
        requests that finished this round."""
        self._admit()
        finished = []
        for i, req in enumerate(self.live):
            sub = jax.random.fold_in(key, req.uid * 1000 + req.blocks)
            prefix = np.concatenate([req.prompt,
                                     np.asarray(req.output, np.int32)])
            buf_len = len(req.prompt) + req.max_new + \
                self.engine.cfg.draft_len + 2
            new, acc = self.engine._gen_block(sub, prefix, buf_len)
            req.output.extend(new)
            req.blocks += 1
            req.accepted += acc
            if req.t_first is None:
                req.t_first = time.time()
            if req.done:
                req.output = req.output[:req.max_new]
                req.t_done = time.time()
                finished.append(req)
        for req in finished:
            self.live.remove(req)
            self.metrics.completed += 1
            self.metrics.total_tokens += len(req.output)
            self.metrics.total_blocks += req.blocks
        return finished

    def run(self, key: jax.Array) -> list:
        """Drain the queue; returns all completed requests in finish order."""
        t0 = time.time()
        done = []
        round_idx = 0
        while self.queue or self.live:
            done.extend(self.step(jax.random.fold_in(key, round_idx)))
            round_idx += 1
        self.metrics.wall_s = time.time() - t0
        return done
