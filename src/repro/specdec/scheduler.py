"""Batched request scheduler for speculative-decoding serving.

A minimal continuous-batching-lite scheduler: requests join a queue, up
to ``max_batch`` live requests advance one speculative block per round
(each with its own RNG stream), finished requests leave and queued ones
join at round boundaries.  Tracks the serving metrics a deployment would
export: time-to-first-block, tokens/s, block efficiency, acceptance
rate, host-sync counts.

All execution modes share one policy (admission order, RNG derivation,
buffer sizing), so their outputs are bit-identical:

  * sequential (``batched=False``): one engine block per live request per
    round — R target forwards per round;
  * batched (``batched=True``): all live requests' draft buffers stack
    into (R*K, T) model calls via ``SpecDecEngine.gen_blocks`` — ONE
    target forward per round regardless of R;
  * kv (``cache_mode="kv"``): a ``CachedSpecDecEngine`` keeps every live
    request's target and drafter caches resident in a slot-based cache
    pool across rounds (admit on first block, release on completion) —
    one drafter decode sweep plus ONE stacked ``verify_step`` per round,
    no per-block re-prefill (DESIGN.md §7).  The first two modes
    re-score the whole prefix every block, O(T^2) per request;
  * kv_fused (``cache_mode="kv_fused"``): same engine and pool, but the
    whole round — drafter sweep, stacked verify, Algorithm-2
    verification, rollback, catch-up — runs as ONE jitted device
    program (DESIGN.md §8): no per-draft-step host transfer
    (``draft_syncs == 0``) and exactly one host sync per round.

RNG streams are derived per request as
``fold_in(fold_in(key, uid), blocks)`` — NESTED folds, because the
flat ``fold_in(key, uid * 1000 + blocks)`` encoding collides across
requests once a request reaches 1000 blocks (uid 1 block 1000 == uid 2
block 0), silently coupling two requests' draws.  ``run()`` feeds the
SAME key to every round, so a request's stream depends only on
(uid, blocks), never on WHICH round a block lands in — that round-
independence is what lets kv_fused defer a newly admitted request's
first block to the round after its overlapped prefill (DESIGN.md §9)
while staying bit-identical to the modes that run it immediately.
(The former per-round ``fold_in(key, round_idx)`` would have tied
every block's randomness to the admission policy.)

Admission (``admission="bucketed"``, the default) drains the queue
into the engine's bucketed batched-prefill waves; under kv_fused the
wave's prefills are dispatched while the current round runs and the
admitted requests join the live set next round.  ``per_request`` keeps
the one-prefill-pair-per-request reference path (the TTFT baseline in
the bursty-admission bench).

Buffer lengths grow monotonically to the largest live requirement
(queued requests count from their admission round), so a request's
compiled shapes — and therefore its sampled tokens — never depend on
which mode ran it (trailing-buffer content does not affect causal
logits, but buffer LENGTH changes compiled reduction shapes, so it is
pinned scheduler-side).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import jax
import numpy as np

from repro.models.cache_pool import PagePoolExhausted
from repro.serving.faults import (
    FAULT_KINDS,
    FaultPlan,
    InjectedFault,
    poison_outcome,
)
from repro.serving.guard import (
    GuardViolation,
    InvalidRequest,
    RoundWatchdog,
    WatchdogTimeout,
    validate_outcome,
    validate_prompt,
)
from repro.specdec.engine import SpecDecConfig, SpecDecEngine


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new: int
    # v2 policy inputs (DESIGN.md §12): higher priority admits first and
    # is never evicted for a lower-priority candidate; ``on_token``
    # streams tokens as their round commits instead of at completion.
    priority: int = 0
    on_token: Optional[Callable] = None
    # runtime state
    output: list = dataclasses.field(default_factory=list)
    blocks: int = 0
    accepted: int = 0
    t_submit: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    # Honest eviction accounting: ``t_submit`` is never reset, so TTFT
    # and wall_s keep covering time spent evicted; ``evicted_s`` breaks
    # out how much of that wall a request spent OUT of the live set
    # after having been admitted at least once, and ``token_times``
    # (one wall-clock stamp per emitted token, shared with the
    # ``on_token`` callback order) makes inter-token gaps — including
    # the gap spanning an eviction — directly measurable.
    evictions: int = 0
    evicted_s: float = 0.0
    token_times: list = dataclasses.field(default_factory=list)
    tokens_since_admit: int = 0
    t_admit: Optional[float] = None
    _t_evict: Optional[float] = None
    # Suspend handle (paged engines): a preempted request keeps its KV
    # pages here and resumes by table re-attach — no re-prefill.  Page
    # pressure may strip the handle (``drop_handle``), demoting it to
    # an ordinary evicted request that re-prefills on re-admission.
    _kv_handle: Optional[dict] = None
    # Fault accounting (DESIGN.md §13): ``retries`` counts rounds this
    # request was displaced from by an ATTRIBUTED fault — a separate
    # counter from ``evictions`` so fault replay never perturbs the v2
    # admission rank.  Past the retry budget the request quarantines:
    # ``error`` is set and it moves to ``server.failed``.
    retries: int = 0
    error: Optional[str] = None

    @property
    def done(self) -> bool:
        return len(self.output) >= self.max_new

    @property
    def block_efficiency(self) -> float:
        return len(self.output) / max(self.blocks, 1)

    @property
    def ttft_ms(self) -> Optional[float]:
        """Time-to-first-token: submission to first emitted tokens."""
        if self.t_first is None:
            return None
        return (self.t_first - self.t_submit) * 1e3

    @property
    def wall_s(self) -> Optional[float]:
        """Submission to completion — eviction time included."""
        if self.t_done is None:
            return None
        return self.t_done - self.t_submit

    @property
    def itl_ms(self) -> list:
        """Inter-token latencies (ms) between consecutive emitted
        tokens.  Tokens committed by the same round share a timestamp
        (gap 0); the gap that spans an eviction/re-admission cycle
        carries the full evicted time — nothing vanishes."""
        t = self.token_times
        return [(b - a) * 1e3 for a, b in zip(t, t[1:])]


@dataclasses.dataclass
class ServerMetrics:
    completed: int = 0
    total_tokens: int = 0
    total_blocks: int = 0
    rounds: int = 0
    target_forwards: int = 0
    host_syncs: int = 0          # verification device->host transfers
    draft_syncs: int = 0         # draft-token materialization transfers
    evictions: int = 0           # capacity evictions (v2 policy)
    preemptions: int = 0         # max-token fairness preemptions (v2)
    # Wall time is accumulated per ``step()`` call, so ``tokens_per_s``
    # is meaningful whether callers drive ``run()`` or ``step()``
    # directly (``run()`` previously set it; direct ``step()`` callers
    # divided by the 1e-9 floor and reported nonsense).
    wall_s: float = 0.0
    # Fault tolerance (DESIGN.md §13).  Every guarded fault increments
    # exactly one ``faults[kind]`` entry AND ``retries`` (one discarded
    # round each), so ``retries == faults_total`` is a consistency
    # invariant the chaos bench gates on.
    faults: dict = dataclasses.field(default_factory=dict)
    retries: int = 0             # rounds discarded and replayed
    quarantined: int = 0         # requests failed past the retry budget
    watchdog_trips: int = 0      # rounds that overran the timeout
    watchdog_accepts: int = 0    # slow-but-valid rounds kept (anti-livelock)
    callback_errors: int = 0     # on_token callbacks that raised
    degradations: list = dataclasses.field(default_factory=list)

    @property
    def faults_total(self) -> int:
        return sum(self.faults.values())

    @property
    def tokens_per_s(self) -> float:
        return self.total_tokens / max(self.wall_s, 1e-9)

    @property
    def mean_block_efficiency(self) -> float:
        return self.total_tokens / max(self.total_blocks, 1)


CACHE_MODES = ("reprefill", "kv", "kv_fused")
ADMISSION_MODES = ("bucketed", "per_request")
POLICIES = ("fifo", "v2")


class SpecDecServer:
    """Round-robin block scheduler over a shared engine.

    ``cache_mode="reprefill"`` drives a reference ``SpecDecEngine``
    (stateless; full-prefix re-score per block, sequential or batched);
    ``cache_mode="kv"`` drives a ``CachedSpecDecEngine`` whose cache
    pool must have at least ``max_batch`` slots — requests are admitted
    to a slot at their first block and released on completion, and every
    round is one batched arena step (``batched`` is implied);
    ``cache_mode="kv_fused"`` is the same serving policy with the round
    executed as one fused device program (DESIGN.md §8).

    ``admission`` picks the cached-engine prefill path: "bucketed"
    (default — batched bucketed waves straight into pool slots,
    overlapped with the running round under kv_fused, DESIGN.md §9) or
    "per_request" (the reference path; also the TTFT baseline in the
    bursty-admission bench).  The policy is passed through to the
    engine per call, never written onto it.

    ``policy`` selects the admission/eviction policy (DESIGN.md §12):

      * "fifo" (default): the original behaviour — queue drains in
        submission order up to ``max_batch``, a live request holds its
        slot until completion, no eviction.
      * "v2": continuous batching with eviction and fairness.  Queued
        requests admit in (priority desc, evictions asc, submit order)
        — the evictions term rotates preempted requests behind waiting
        peers of equal priority.  A candidate that cannot fit (batch
        full, or — under a fixed paged KV budget — its worst-case page
        commitment would oversubscribe the pool) may DISPLACE strictly
        lower-priority live requests.  On a paged engine displacement
        SUSPENDS: the victim's KV pages detach into a handle (the slot
        frees, the pages stay resident and unwritable) and re-admission
        is a host table re-attach — no recompute, so preemption costs
        ~nothing.  Page pressure can strip a suspended handle (worst-
        ranked first), demoting the holder to a hard eviction that
        re-admits via chunked re-prefill of prompt+output; non-paged
        engines always take that path.  Both are token-invisible:
        per-request randomness is (uid, blocks)-keyed, resumed pages
        are the same bytes, and re-prefilled KV is bitwise equal to
        the decode-built KV it replaces.  ``preempt_tokens=N``
        additionally preempts any live request that has emitted ≥ N
        tokens since its last admission while others wait — bounding
        tail TTFT under a few long-running requests.

    ``min_buf_len`` pins the starting decode-buffer length.  Buffer
    length changes compiled reduction shapes (module docstring), and
    under v2 WHICH requests are live — and therefore the natural buffer
    growth schedule — depends on wall-clock arrival order; pinning the
    buffer to the trace's maximum requirement makes outputs bit-
    comparable across policies and load patterns.
    """

    def __init__(self, engine, max_batch: int = 8,
                 batched: bool = False, cache_mode: str = "reprefill",
                 admission: str = "bucketed", policy: str = "fifo",
                 preempt_tokens: Optional[int] = None,
                 min_buf_len: int = 0,
                 fault_plan: Optional[FaultPlan] = None,
                 retry_budget: Optional[int] = None,
                 round_timeout_ms: Optional[float] = None,
                 degrade_after: Optional[int] = None):
        if cache_mode not in CACHE_MODES:
            raise ValueError(f"unknown cache_mode {cache_mode!r}")
        if admission not in ADMISSION_MODES:
            raise ValueError(f"unknown admission mode {admission!r}")
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}")
        if policy == "v2" and cache_mode not in ("kv", "kv_fused"):
            raise ValueError(
                "policy='v2' needs cache_mode 'kv' or 'kv_fused' — "
                "eviction releases engine sessions")
        if preempt_tokens is not None:
            if policy != "v2":
                raise ValueError("preempt_tokens needs policy='v2'")
            if preempt_tokens < 1:
                raise ValueError("preempt_tokens must be >= 1")
        if cache_mode in ("kv", "kv_fused"):
            if not hasattr(engine, "admit"):
                raise TypeError(
                    f"cache_mode={cache_mode!r} needs a CachedSpecDecEngine")
            if engine.pool_slots < max_batch:
                raise ValueError(
                    f"engine pool has {engine.pool_slots} slots < "
                    f"max_batch={max_batch}")
        self.engine = engine
        self.max_batch = max_batch
        self.batched = batched
        self.cache_mode = cache_mode
        self.admission = admission
        self.policy = policy
        self.preempt_tokens = preempt_tokens
        self.queue: deque = deque()
        self.live: list = []
        self._uid = 0
        self._buf_len = max(0, int(min_buf_len))
        self.metrics = ServerMetrics()
        # Fault tolerance (DESIGN.md §13).  ``guarded`` turns on round
        # recovery; it is implied by passing ANY fault-layer knob, so a
        # server with none of them behaves byte-for-byte like before
        # (faults propagate, the fifo page-exhaustion test stays loud).
        if retry_budget is not None and retry_budget < 0:
            raise ValueError("retry_budget must be >= 0")
        if round_timeout_ms is not None and round_timeout_ms <= 0:
            raise ValueError("round_timeout_ms must be > 0")
        if degrade_after is not None and degrade_after < 1:
            raise ValueError("degrade_after must be >= 1")
        self.fault_plan = fault_plan
        self.guarded = (fault_plan is not None or retry_budget is not None
                        or round_timeout_ms is not None
                        or degrade_after is not None)
        self.retry_budget = 2 if retry_budget is None else int(retry_budget)
        self.round_timeout_ms = round_timeout_ms
        self.degrade_after = degrade_after
        # Requests that FAILED (quarantine, callback error) — disjoint
        # from the completed list ``run()`` returns.
        self.failed: list = []
        self._consec_faults = 0
        self._consec_wd = 0

    def submit(self, prompt: np.ndarray, max_new: int = 32, *,
               priority: int = 0, on_token: Optional[Callable] = None) -> int:
        """Queue a request.  ``priority`` orders v2 admission (ignored
        under fifo); ``on_token(uid, token)`` is called once per emitted
        token, at the round commit that produced it, in emission
        order.  Malformed inputs (empty prompt, non-integer dtype,
        out-of-vocab ids, ``max_new < 1``) raise ``InvalidRequest``
        HERE, at the API boundary, instead of surfacing as a cryptic
        device-side failure rounds later."""
        prompt = validate_prompt(prompt, max_new,
                                 getattr(self.engine, "vocab", None))
        self._uid += 1
        req = Request(uid=self._uid, prompt=prompt,
                      max_new=int(max_new), priority=priority,
                      on_token=on_token, t_submit=time.time())
        self.queue.append(req)
        return req.uid

    # ---- admission / eviction policy ---------------------------------

    @staticmethod
    def _order(req: Request):
        """v2 queue order: priority first, then rotate evicted/preempted
        requests behind same-priority waiters, then submission order."""
        return (-req.priority, req.evictions, req.t_submit, req.uid)

    def _mark_admitted(self, req: Request, now: float) -> None:
        if req._t_evict is not None:
            req.evicted_s += now - req._t_evict
            req._t_evict = None
        req.t_admit = now
        req.tokens_since_admit = 0

    def _evict(self, req: Request, now: float) -> None:
        """Displace ``req`` from the live set and requeue it.  On a
        paged engine this SUSPENDS: the request's KV pages detach into
        a handle (``Request._kv_handle``) and re-admission is a table
        re-attach — no recompute.  Otherwise (or after the handle is
        stripped under page pressure) the session is released outright
        and re-admission re-prefills prompt+output, which rebuilds KV
        bitwise equal to the state just dropped — either way the
        displacement is token-invisible (DESIGN.md §12)."""
        self.live.remove(req)
        if self.engine.has_session(req.uid):
            if getattr(self.engine, "can_suspend", lambda: False)():
                req._kv_handle = self.engine.suspend(req.uid)
            else:
                self.engine.evict(req.uid)
        req.evictions += 1
        req._t_evict = now
        self.queue.append(req)

    def _lifetime_pages(self, req: Request) -> int:
        """Worst-case page commitment: the pages ``req`` will hold once
        fully decoded.  Admission against lifetime commitments (not
        current holdings) guarantees mid-round ``reserve`` can never
        exhaust a fixed page budget."""
        return self.engine.request_pages(len(req.prompt) + req.max_new)

    def _pick_victim(self, below_priority: int, protect: set):
        """Lowest-priority live request strictly below
        ``below_priority`` (never admitted this step), shortest prefix
        first — the cheapest re-prefill loses its slot."""
        cands = [r for r in self.live
                 if r.priority < below_priority and id(r) not in protect]
        if not cands:
            return None
        return min(cands, key=lambda r: (r.priority,
                                         len(r.prompt) + len(r.output),
                                         r.uid))

    def _admit_v2(self, now: float) -> list:
        page_state = self.engine.page_state()
        fixed = bool(page_state and page_state.get("fixed"))
        newly: list = []
        protect: set = set()
        while self.queue:
            cand = min(self.queue, key=self._order)
            blocked_by_pages = False
            if fixed:
                # Pages spoken for: live requests count their LIFETIME
                # commitment (they grow every round, worst case to full
                # decode); suspended queue entries count their handle's
                # actual holdings (detached chains never grow — growth
                # re-enters through this same check at resume, when the
                # resumed request's lifetime is charged as ``need``).
                committed = sum(self._lifetime_pages(r) for r in self.live)
                committed += sum(self.engine.handle_pages(q._kv_handle)
                                 for q in self.queue
                                 if q._kv_handle is not None and q is not cand)
                need = self._lifetime_pages(cand)
                if need > page_state["total"]:
                    raise ValueError(
                        f"request uid={cand.uid} needs {need} pages but "
                        f"the pool only has {page_state['total']}")
                blocked_by_pages = committed + need > page_state["total"]
            if len(self.live) >= self.max_batch or blocked_by_pages:
                # Page pressure reclaims from suspended holders first:
                # stripping the worst-ranked handle behind ``cand``
                # frees pages without touching the live set (the holder
                # re-admits later via re-prefill).  Handles ranked
                # AHEAD of cand are never stripped — those requests
                # resume before cand anyway.
                if blocked_by_pages and len(self.live) < self.max_batch:
                    holders = [q for q in self.queue
                               if q._kv_handle is not None and q is not cand
                               and self._order(q) > self._order(cand)]
                    if holders:
                        worst = max(holders, key=self._order)
                        self.engine.drop_handle(worst._kv_handle)
                        worst._kv_handle = None
                        self.metrics.evictions += 1
                        continue
                victim = self._pick_victim(cand.priority, protect)
                if victim is None:
                    break
                self._evict(victim, now)
                self.metrics.evictions += 1
                continue
            self.queue.remove(cand)
            self.live.append(cand)
            protect.add(id(cand))
            self._mark_admitted(cand, now)
            if cand._kv_handle is not None:
                # Resume from the suspend handle: session re-binds to a
                # free slot host-side, KV already resident — the request
                # advances THIS round (no prefill to overlap), which is
                # token-invisible because randomness is (uid, blocks)-
                # keyed, never round-keyed.
                self.engine.resume(cand.uid, cand._kv_handle)
                cand._kv_handle = None
            else:
                newly.append(cand)
        return newly

    def _preempt(self, now: float) -> None:
        """Fairness rotation: while requests wait in the queue, evict
        live requests that have emitted ``preempt_tokens`` or more
        tokens since their last admission.  Their incremented eviction
        count sorts them behind same-priority waiters, so slots rotate
        instead of ping-ponging."""
        if not self.preempt_tokens or not self.queue:
            return
        for req in list(self.live):
            if req.done or req.tokens_since_admit < self.preempt_tokens:
                continue
            # Only preempt when some waiter would actually outrank the
            # displaced request in the admission order — otherwise the
            # eviction is pure churn: the same request re-admits
            # immediately and pays a re-prefill for nothing (a high-
            # priority request is never preempted for low-priority
            # waiters).
            displaced = (-req.priority, req.evictions + 1,
                         req.t_submit, req.uid)
            if not any(self._order(q) < displaced for q in self.queue):
                continue
            self._evict(req, now)
            self.metrics.preemptions += 1

    def _admit(self) -> list:
        """Move queued requests into the live set; returns the newly
        admitted requests."""
        now = time.time()
        if self.policy == "v2":
            self._preempt(now)
            return self._admit_v2(now)
        newly = []
        while self.queue and len(self.live) < self.max_batch:
            req = self.queue.popleft()
            self.live.append(req)
            newly.append(req)
            self._mark_admitted(req, now)
        return newly

    def _required_buf(self, req: Request) -> int:
        return len(req.prompt) + req.max_new + self.engine.cfg.draft_len + 2

    # Faults the guarded scheduler recovers from; anything else stays
    # loud.  GuardViolation subclasses AssertionError, but a PLAIN
    # AssertionError (an engine contract bug) is never recoverable.
    _RECOVERABLE = (InjectedFault, PagePoolExhausted, GuardViolation,
                    WatchdogTimeout, MemoryError)

    def _is_recoverable(self, e: BaseException) -> bool:
        if isinstance(e, self._RECOVERABLE):
            return True
        # Real allocator failures surface as XLA RESOURCE_EXHAUSTED.
        return isinstance(e, RuntimeError) and "RESOURCE_EXHAUSTED" in str(e)

    def _vocab(self) -> Optional[int]:
        return getattr(self.engine, "vocab", None)

    def step(self, key: jax.Array) -> list:
        """Advance every live request by one speculative block.  Returns
        requests that finished this round.

        Under kv_fused with bucketed admission, requests admitted THIS
        step only prefill (overlapped with the round advancing the
        previously admitted requests, DESIGN.md §9) and start emitting
        tokens next step.  Round-alignment differences between modes
        are token-invisible because per-request randomness depends only
        on (uid, blocks) — callers comparing admission policies must
        pass the same ``key`` every step, as ``run()`` does.

        On a guarded server (DESIGN.md §13) a recoverable fault makes
        the step return [] after displacing the round's requests; the
        next step replays them bit-identically — ``blocks`` only
        advances at commit, so the re-derived (uid, blocks) stream is
        the same sheet the discarded round drew."""
        t0 = time.perf_counter()
        try:
            newly = self._admit()
            if not self.live:
                return []
            self._buf_len = max([self._buf_len]
                                + [self._required_buf(r)
                                   for r in self.live])
            overlap = (self.cache_mode == "kv_fused"
                       and self.admission == "bucketed")
            new_ids = {id(r) for r in newly}
            advancing = [r for r in self.live if id(r) not in new_ids] \
                if overlap else self.live
            # Nested folds: a flat uid * C + blocks encoding collides
            # across requests once blocks reaches C (module docstring).
            subs = [jax.random.fold_in(jax.random.fold_in(key, r.uid),
                                       r.blocks)
                    for r in advancing]
            fw0 = self.engine.num_target_forwards
            ds0 = getattr(self.engine, "num_draft_syncs", 0)
            try:
                outs = self._dispatch(subs, advancing, newly, overlap)
            except Exception as fault:
                if not (self.guarded and self._is_recoverable(fault)):
                    raise
                self._recover(fault, newly)
                return []
            if advancing:
                self.metrics.rounds += 1
            self.metrics.target_forwards += \
                self.engine.num_target_forwards - fw0
            self.metrics.draft_syncs += (
                getattr(self.engine, "num_draft_syncs", 0) - ds0)
            finished = self._commit(advancing, outs)
            self._consec_faults = 0
            return finished
        finally:
            self.metrics.wall_s += time.perf_counter() - t0

    def _engine_round(self, subs, advancing, newly, overlap) -> list:
        """One engine round — the three execution branches."""
        if overlap:
            # The overlap path skips full-prefix assembly (the engine
            # serves from cached state) but still hands over each
            # request's last emitted token so the engine can enforce
            # the prefix-tail == pending contract loudly.
            tails = [int(r.output[-1]) if r.output else int(r.prompt[-1])
                     for r in advancing]
            # Admission prefixes carry prompt+output: a re-admitted
            # (evicted) request re-prefills everything it has emitted
            # so far, rebuilding KV bitwise equal to the state it lost.
            # For fresh requests output is empty and this is the prompt.
            return self.engine.round_with_admission(
                subs, [r.uid for r in advancing],
                [(r.uid, np.concatenate([r.prompt,
                                         np.asarray(r.output, np.int32)]))
                 for r in newly], self._buf_len,
                tails=tails)
        prefixes = [np.concatenate([r.prompt,
                                    np.asarray(r.output, np.int32)])
                    for r in advancing]
        if self.cache_mode in ("kv", "kv_fused"):
            return self.engine.gen_blocks(
                subs, prefixes, self._buf_len,
                uids=[r.uid for r in advancing],
                fused=self.cache_mode == "kv_fused",
                admission=self.admission)
        if self.batched:
            return self.engine.gen_blocks(subs, prefixes, self._buf_len)
        return [self.engine.gen_block(sub, prefix, self._buf_len)
                for sub, prefix in zip(subs, prefixes)]

    def _dispatch(self, subs, advancing, newly, overlap) -> list:
        """Run one engine round under the fault layer (DESIGN.md §13):
        pre-call injections fire before the engine is touched, the
        watchdog times the blocking call, post-call injections and the
        outcome guard run on the results.  Injection draws are keyed by
        (kind, uid, blocks, retries) — fully deterministic, and a
        replay re-draws at the same rate because the attributed
        request's retry counter advanced."""
        plan = self.fault_plan
        post = []
        if plan is not None:
            for req in advancing:
                for kind in FAULT_KINDS:
                    if not plan.fires(kind, req.uid, req.blocks,
                                      req.retries):
                        continue
                    if kind in ("pool_exhausted", "oom"):
                        # Pre-call: the engine never runs, session
                        # state stays clean (suspend-capable recovery).
                        raise InjectedFault(kind, uid=req.uid, phase="pre")
                    post.append((kind, req))
        wd = RoundWatchdog(self.round_timeout_ms)
        with wd:
            outs = self._engine_round(subs, advancing, newly, overlap)
            for kind, req in post:
                if kind == "slow_round":
                    time.sleep(plan.slow_ms / 1e3)
        # The valve only engages on rounds that ADVANCE requests: an
        # admission-only round (overlap mode right after displacement)
        # must neither raise — discarding it re-does the same prefill —
        # nor reset the consecutive-trip counter, which would starve
        # the advancing rounds of ever reaching the accept valve.
        if wd.tripped and advancing:
            self.metrics.watchdog_trips += 1
            self._consec_wd += 1
            if self._consec_wd > max(1, self.retry_budget):
                # Anti-livelock valve: the round's results are VALID,
                # just late.  On a genuinely slow machine, discarding
                # forever would wedge the drain loop — accept the slow
                # round instead and record that we did.
                self.metrics.watchdog_accepts += 1
                self._consec_wd = 0
            else:
                slow = next((r for k, r in post if k == "slow_round"),
                            None)
                if slow is not None:
                    raise InjectedFault("slow_round", uid=slow.uid,
                                        phase="post")
                raise WatchdogTimeout(
                    f"round exceeded {self.round_timeout_ms}ms")
        elif advancing:
            self._consec_wd = 0
        for kind, req in post:
            if kind == "kernel_dispatch":
                raise InjectedFault(kind, uid=req.uid, phase="post")
        poisoned_uids = set()
        if post:
            idx = {id(r): i for i, r in enumerate(advancing)}
            for kind, req in post:
                if kind == "nan_logits":
                    outs[idx[id(req)]] = poison_outcome(
                        outs[idx[id(req)]], self._vocab(), req.uid)
                    poisoned_uids.add(req.uid)
        if self.guarded:
            lr = self.engine.cfg.draft_len
            for req, out in zip(advancing, outs):
                try:
                    validate_outcome(out, req.uid, self._vocab(), lr)
                except GuardViolation:
                    if req.uid not in poisoned_uids:
                        raise
                    # The guard caught OUR injection: attribute it to
                    # the injected class (recovery scrubs either way —
                    # both are poisoning kinds), so the fault counters
                    # separate injected NaN rounds from genuine
                    # corruption ("guard").
                    raise InjectedFault("nan_logits", uid=req.uid,
                                        phase="post")
        return outs

    def _commit(self, advancing, outs) -> list:
        """Commit a validated round: emit tokens (streaming callbacks
        fire here), retire finished requests, isolate callback
        failures."""
        finished, cb_failed = [], []
        t_commit = time.time()
        for req, out in zip(advancing, outs):
            # Emit only up to max_new: the block may overshoot on its
            # last round, and streamed tokens / timestamps must match
            # the final (trimmed) output exactly.
            emit = list(out.new_tokens)[:req.max_new - len(req.output)]
            req.output.extend(emit)
            req.blocks += 1
            # A committed round is progress: quarantine is for
            # PERSISTENT failure, so the budget counts CONSECUTIVE
            # attributed faults, not lifetime ones — a long request
            # under steady background chaos must not accumulate its
            # way into quarantine.
            req.retries = 0
            req.accepted += out.accepted
            req.tokens_since_admit += len(emit)
            self.metrics.host_syncs += out.verify_syncs
            if req.t_first is None:
                req.t_first = t_commit
            for tok in emit:
                req.token_times.append(t_commit)
                if req.on_token is not None:
                    try:
                        req.on_token(req.uid, int(tok))
                    except Exception as e:
                        # User callback code: a raising callback fails
                        # only ITS request — never the drain loop.
                        req.on_token = None
                        req.error = f"on_token callback raised: {e!r}"
                        cb_failed.append(req)
                        self.metrics.callback_errors += 1
            if req.error is None and req.done:
                req.t_done = t_commit
                finished.append(req)
        for req in cb_failed:
            # The failed request's slot (and pages) release; committed
            # tokens stay on the record for the postmortem.
            self.live.remove(req)
            if hasattr(self.engine, "has_session") \
                    and self.engine.has_session(req.uid):
                self.engine.release(req.uid)
            self.failed.append(req)
        for req in finished:
            self.live.remove(req)
            if self.cache_mode in ("kv", "kv_fused"):
                self.engine.release(req.uid)
            self.metrics.completed += 1
            self.metrics.total_tokens += len(req.output)
            self.metrics.total_blocks += req.blocks
        return finished

    # ---- fault recovery (DESIGN.md §13) ------------------------------

    def _recover(self, fault, newly) -> None:
        """Guarded-fault recovery: displace every request the round
        touched, discard round-scoped device state, attribute the
        fault, and (optionally) step the degradation ladder.  Replay is
        exact for free: per-request randomness is (uid, blocks)-keyed
        and ``blocks`` only advances at commit, so the re-executed
        round draws the very sheet the discarded round drew, and
        re-prefilled KV is bitwise equal to the decode-built KV it
        replaces."""
        now = time.time()
        kind = getattr(fault, "kind", None)
        if kind is None:
            kind = "pool_exhausted" \
                if isinstance(fault, PagePoolExhausted) else "oom"
        phase = getattr(fault, "phase",
                        "pre" if isinstance(fault, PagePoolExhausted)
                        else "post")
        poisoned = kind in ("nan_logits", "guard")
        uid = getattr(fault, "uid", None)
        self.metrics.faults[kind] = self.metrics.faults.get(kind, 0) + 1
        self.metrics.retries += 1
        self._consec_faults += 1

        # Displace everyone.  Post-phase faults advanced session state
        # (pending / device positions) past what the host committed, so
        # those sessions hard-evict and replay from prompt+output;
        # pre-phase faults left sessions clean, so a paged v2 engine
        # SUSPENDS instead (pages stay resident — this is how a real
        # ``PagePoolExhausted`` converts into displacement: suspend the
        # holders, let v2 admission strip handles under pressure, hard-
        # evict last).  Poisoned rounds always hard-evict — suspended
        # pages would keep possibly-NaN bytes alive across the scrub.
        can_suspend = (self.policy == "v2" and not poisoned
                       and phase == "pre"
                       and getattr(self.engine, "can_suspend",
                                   lambda: False)())
        new_ids = {id(r) for r in newly}
        displaced = list(self.live)
        self.live.clear()
        for req in displaced:
            if hasattr(self.engine, "has_session") \
                    and self.engine.has_session(req.uid):
                if can_suspend and id(req) not in new_ids:
                    req._kv_handle = self.engine.suspend(req.uid)
                else:
                    self.engine.evict(req.uid)
            req._t_evict = now
        # Requeue at the FRONT in original order; ``evictions`` stays
        # untouched — fault displacement is not a policy rotation, and
        # bumping it would perturb the v2 admission rank (and with it
        # the token-invisible replay schedule).
        self.queue.extendleft(reversed(displaced))
        if poisoned:
            # The scrub rebuilds KV storage; a suspended handle's
            # detached pages may hold poisoned bytes, so forfeit them
            # first (the holders re-prefill — exact, by the same
            # bit-identity argument as eviction).
            for q in self.queue:
                if q._kv_handle is not None:
                    self.engine.drop_handle(q._kv_handle)
                    q._kv_handle = None
        if hasattr(self.engine, "discard_round_state"):
            self.engine.discard_round_state(scrub=poisoned)

        if uid is not None:
            req = next((r for r in displaced if r.uid == uid), None)
            if req is not None:
                req.retries += 1
                if req.retries > self.retry_budget:
                    self._quarantine(
                        req, f"retry budget ({self.retry_budget}) "
                             f"exhausted by repeated {kind} faults")
        stepped = False
        if self.degrade_after \
                and self._consec_faults >= self.degrade_after:
            stepped = self._degrade()
            if stepped:
                self._consec_faults = 0
        if not stepped and uid is None \
                and self._consec_faults > max(1, self.retry_budget):
            # An unattributed fault recurring with no ladder rung left:
            # re-raise rather than retry forever.
            raise fault

    def _quarantine(self, req: Request, reason: str) -> None:
        """Permanently fail a request: out of the queue, suspend handle
        forfeited, error recorded.  Its engine session is already gone
        (recovery displaced it before attribution)."""
        if req in self.queue:
            self.queue.remove(req)
        if req._kv_handle is not None:
            self.engine.drop_handle(req._kv_handle)
            req._kv_handle = None
        req.error = f"quarantined: {reason}"
        self.failed.append(req)
        self.metrics.quarantined += 1

    def _ladder_next(self) -> Optional[str]:
        """The next degradation rung, or None at the bottom.  Rungs
        step from the most-optimized execution mode toward the
        stateless reference, and every rung except dequant is
        bit-identical (DESIGN.md §13):

          pallas verifier -> xla   (exact-equality oracles)
          quant verify -> f32      (acceptance-equivalent)
          kv_fused -> kv           (same tokens, host-driven round)
          kv -> reprefill          (same tokens, stateless reference)
        """
        cfg = getattr(self.engine, "cfg", None)
        if cfg is not None and cfg.verifier_backend == "pallas" \
                and hasattr(self.engine, "set_verifier_backend"):
            return "verifier:pallas->xla"
        if cfg is not None and getattr(cfg, "quant", False) \
                and hasattr(self.engine, "dequantize_verify") \
                and not getattr(self.engine, "_verify_dequantized", False):
            return "verify:quant->f32"
        if self.cache_mode == "kv_fused":
            return "cache:kv_fused->kv"
        if self.cache_mode == "kv":
            return "cache:kv->reprefill"
        return None

    def _degrade(self) -> bool:
        """Step one rung down the degradation ladder; returns whether a
        step was taken.  Transitions are sticky (the ladder never
        climbs back mid-serve — a flapping mode would re-trigger
        whatever broke the faster one) and recorded in
        ``metrics.degradations``."""
        step = self._ladder_next()
        if step is None:
            return False
        if step == "verifier:pallas->xla":
            self.engine.set_verifier_backend("xla")
        elif step == "verify:quant->f32":
            self.engine.dequantize_verify()
        elif step == "cache:kv_fused->kv":
            self.cache_mode = "kv"
        else:  # cache:kv->reprefill
            # The reference path is stateless: no sessions, no resume —
            # strip any suspended handle (the holders re-prefill) and
            # stack the reference rounds into batched forwards.
            for q in self.queue:
                if q._kv_handle is not None:
                    self.engine.drop_handle(q._kv_handle)
                    q._kv_handle = None
            self.cache_mode = "reprefill"
            self.batched = True
        self.metrics.degradations.append(
            {"round": self.metrics.rounds, "step": step})
        return True

    def run(self, key: jax.Array) -> list:
        """Drain the queue; returns all completed requests in finish order.
        Wall time accrues inside ``step()`` (shared with direct-step
        callers), so this loop adds no timing of its own.  The SAME key
        feeds every round — per-request streams are (uid, blocks)-keyed
        (module docstring), so which round a block lands in never
        changes its randomness."""
        done = []
        while self.queue or self.live:
            done.extend(self.step(key))
        return done
