"""Batched request scheduler for speculative-decoding serving.

A minimal continuous-batching-lite scheduler: requests join a queue, up
to ``max_batch`` live requests advance one speculative block per round
(each with its own RNG stream), finished requests leave and queued ones
join at round boundaries.  Tracks the serving metrics a deployment would
export: time-to-first-block, tokens/s, block efficiency, acceptance
rate, host-sync counts.

All execution modes share one policy (admission order, RNG derivation,
buffer sizing), so their outputs are bit-identical:

  * sequential (``batched=False``): one engine block per live request per
    round — R target forwards per round;
  * batched (``batched=True``): all live requests' draft buffers stack
    into (R*K, T) model calls via ``SpecDecEngine.gen_blocks`` — ONE
    target forward per round regardless of R;
  * kv (``cache_mode="kv"``): a ``CachedSpecDecEngine`` keeps every live
    request's target and drafter caches resident in a slot-based cache
    pool across rounds (admit on first block, release on completion) —
    one drafter decode sweep plus ONE stacked ``verify_step`` per round,
    no per-block re-prefill (DESIGN.md §7).  The first two modes
    re-score the whole prefix every block, O(T^2) per request;
  * kv_fused (``cache_mode="kv_fused"``): same engine and pool, but the
    whole round — drafter sweep, stacked verify, Algorithm-2
    verification, rollback, catch-up — runs as ONE jitted device
    program (DESIGN.md §8): no per-draft-step host transfer
    (``draft_syncs == 0``) and exactly one host sync per round.

RNG streams are derived per request as
``fold_in(fold_in(key, uid), blocks)`` — NESTED folds, because the
flat ``fold_in(key, uid * 1000 + blocks)`` encoding collides across
requests once a request reaches 1000 blocks (uid 1 block 1000 == uid 2
block 0), silently coupling two requests' draws.  ``run()`` feeds the
SAME key to every round, so a request's stream depends only on
(uid, blocks), never on WHICH round a block lands in — that round-
independence is what lets kv_fused defer a newly admitted request's
first block to the round after its overlapped prefill (DESIGN.md §9)
while staying bit-identical to the modes that run it immediately.
(The former per-round ``fold_in(key, round_idx)`` would have tied
every block's randomness to the admission policy.)

Admission (``admission="bucketed"``, the default) drains the queue
into the engine's bucketed batched-prefill waves; under kv_fused the
wave's prefills are dispatched while the current round runs and the
admitted requests join the live set next round.  ``per_request`` keeps
the one-prefill-pair-per-request reference path (the TTFT baseline in
the bursty-admission bench).

Buffer lengths grow monotonically to the largest live requirement
(queued requests count from their admission round), so a request's
compiled shapes — and therefore its sampled tokens — never depend on
which mode ran it (trailing-buffer content does not affect causal
logits, but buffer LENGTH changes compiled reduction shapes, so it is
pinned scheduler-side).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import jax
import numpy as np

from repro.specdec.engine import SpecDecConfig, SpecDecEngine


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray
    max_new: int
    # v2 policy inputs (DESIGN.md §12): higher priority admits first and
    # is never evicted for a lower-priority candidate; ``on_token``
    # streams tokens as their round commits instead of at completion.
    priority: int = 0
    on_token: Optional[Callable] = None
    # runtime state
    output: list = dataclasses.field(default_factory=list)
    blocks: int = 0
    accepted: int = 0
    t_submit: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    # Honest eviction accounting: ``t_submit`` is never reset, so TTFT
    # and wall_s keep covering time spent evicted; ``evicted_s`` breaks
    # out how much of that wall a request spent OUT of the live set
    # after having been admitted at least once, and ``token_times``
    # (one wall-clock stamp per emitted token, shared with the
    # ``on_token`` callback order) makes inter-token gaps — including
    # the gap spanning an eviction — directly measurable.
    evictions: int = 0
    evicted_s: float = 0.0
    token_times: list = dataclasses.field(default_factory=list)
    tokens_since_admit: int = 0
    t_admit: Optional[float] = None
    _t_evict: Optional[float] = None
    # Suspend handle (paged engines): a preempted request keeps its KV
    # pages here and resumes by table re-attach — no re-prefill.  Page
    # pressure may strip the handle (``drop_handle``), demoting it to
    # an ordinary evicted request that re-prefills on re-admission.
    _kv_handle: Optional[dict] = None

    @property
    def done(self) -> bool:
        return len(self.output) >= self.max_new

    @property
    def block_efficiency(self) -> float:
        return len(self.output) / max(self.blocks, 1)

    @property
    def ttft_ms(self) -> Optional[float]:
        """Time-to-first-token: submission to first emitted tokens."""
        if self.t_first is None:
            return None
        return (self.t_first - self.t_submit) * 1e3

    @property
    def wall_s(self) -> Optional[float]:
        """Submission to completion — eviction time included."""
        if self.t_done is None:
            return None
        return self.t_done - self.t_submit

    @property
    def itl_ms(self) -> list:
        """Inter-token latencies (ms) between consecutive emitted
        tokens.  Tokens committed by the same round share a timestamp
        (gap 0); the gap that spans an eviction/re-admission cycle
        carries the full evicted time — nothing vanishes."""
        t = self.token_times
        return [(b - a) * 1e3 for a, b in zip(t, t[1:])]


@dataclasses.dataclass
class ServerMetrics:
    completed: int = 0
    total_tokens: int = 0
    total_blocks: int = 0
    rounds: int = 0
    target_forwards: int = 0
    host_syncs: int = 0          # verification device->host transfers
    draft_syncs: int = 0         # draft-token materialization transfers
    evictions: int = 0           # capacity evictions (v2 policy)
    preemptions: int = 0         # max-token fairness preemptions (v2)
    # Wall time is accumulated per ``step()`` call, so ``tokens_per_s``
    # is meaningful whether callers drive ``run()`` or ``step()``
    # directly (``run()`` previously set it; direct ``step()`` callers
    # divided by the 1e-9 floor and reported nonsense).
    wall_s: float = 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.total_tokens / max(self.wall_s, 1e-9)

    @property
    def mean_block_efficiency(self) -> float:
        return self.total_tokens / max(self.total_blocks, 1)


CACHE_MODES = ("reprefill", "kv", "kv_fused")
ADMISSION_MODES = ("bucketed", "per_request")
POLICIES = ("fifo", "v2")


class SpecDecServer:
    """Round-robin block scheduler over a shared engine.

    ``cache_mode="reprefill"`` drives a reference ``SpecDecEngine``
    (stateless; full-prefix re-score per block, sequential or batched);
    ``cache_mode="kv"`` drives a ``CachedSpecDecEngine`` whose cache
    pool must have at least ``max_batch`` slots — requests are admitted
    to a slot at their first block and released on completion, and every
    round is one batched arena step (``batched`` is implied);
    ``cache_mode="kv_fused"`` is the same serving policy with the round
    executed as one fused device program (DESIGN.md §8).

    ``admission`` picks the cached-engine prefill path: "bucketed"
    (default — batched bucketed waves straight into pool slots,
    overlapped with the running round under kv_fused, DESIGN.md §9) or
    "per_request" (the reference path; also the TTFT baseline in the
    bursty-admission bench).  The policy is passed through to the
    engine per call, never written onto it.

    ``policy`` selects the admission/eviction policy (DESIGN.md §12):

      * "fifo" (default): the original behaviour — queue drains in
        submission order up to ``max_batch``, a live request holds its
        slot until completion, no eviction.
      * "v2": continuous batching with eviction and fairness.  Queued
        requests admit in (priority desc, evictions asc, submit order)
        — the evictions term rotates preempted requests behind waiting
        peers of equal priority.  A candidate that cannot fit (batch
        full, or — under a fixed paged KV budget — its worst-case page
        commitment would oversubscribe the pool) may DISPLACE strictly
        lower-priority live requests.  On a paged engine displacement
        SUSPENDS: the victim's KV pages detach into a handle (the slot
        frees, the pages stay resident and unwritable) and re-admission
        is a host table re-attach — no recompute, so preemption costs
        ~nothing.  Page pressure can strip a suspended handle (worst-
        ranked first), demoting the holder to a hard eviction that
        re-admits via chunked re-prefill of prompt+output; non-paged
        engines always take that path.  Both are token-invisible:
        per-request randomness is (uid, blocks)-keyed, resumed pages
        are the same bytes, and re-prefilled KV is bitwise equal to
        the decode-built KV it replaces.  ``preempt_tokens=N``
        additionally preempts any live request that has emitted ≥ N
        tokens since its last admission while others wait — bounding
        tail TTFT under a few long-running requests.

    ``min_buf_len`` pins the starting decode-buffer length.  Buffer
    length changes compiled reduction shapes (module docstring), and
    under v2 WHICH requests are live — and therefore the natural buffer
    growth schedule — depends on wall-clock arrival order; pinning the
    buffer to the trace's maximum requirement makes outputs bit-
    comparable across policies and load patterns.
    """

    def __init__(self, engine, max_batch: int = 8,
                 batched: bool = False, cache_mode: str = "reprefill",
                 admission: str = "bucketed", policy: str = "fifo",
                 preempt_tokens: Optional[int] = None,
                 min_buf_len: int = 0):
        if cache_mode not in CACHE_MODES:
            raise ValueError(f"unknown cache_mode {cache_mode!r}")
        if admission not in ADMISSION_MODES:
            raise ValueError(f"unknown admission mode {admission!r}")
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}")
        if policy == "v2" and cache_mode not in ("kv", "kv_fused"):
            raise ValueError(
                "policy='v2' needs cache_mode 'kv' or 'kv_fused' — "
                "eviction releases engine sessions")
        if preempt_tokens is not None:
            if policy != "v2":
                raise ValueError("preempt_tokens needs policy='v2'")
            if preempt_tokens < 1:
                raise ValueError("preempt_tokens must be >= 1")
        if cache_mode in ("kv", "kv_fused"):
            if not hasattr(engine, "admit"):
                raise TypeError(
                    f"cache_mode={cache_mode!r} needs a CachedSpecDecEngine")
            if engine.pool_slots < max_batch:
                raise ValueError(
                    f"engine pool has {engine.pool_slots} slots < "
                    f"max_batch={max_batch}")
        self.engine = engine
        self.max_batch = max_batch
        self.batched = batched
        self.cache_mode = cache_mode
        self.admission = admission
        self.policy = policy
        self.preempt_tokens = preempt_tokens
        self.queue: deque = deque()
        self.live: list = []
        self._uid = 0
        self._buf_len = max(0, int(min_buf_len))
        self.metrics = ServerMetrics()

    def submit(self, prompt: np.ndarray, max_new: int = 32, *,
               priority: int = 0, on_token: Optional[Callable] = None) -> int:
        """Queue a request.  ``priority`` orders v2 admission (ignored
        under fifo); ``on_token(uid, token)`` is called once per emitted
        token, at the round commit that produced it, in emission
        order."""
        self._uid += 1
        req = Request(uid=self._uid, prompt=np.asarray(prompt, np.int32),
                      max_new=max_new, priority=priority, on_token=on_token,
                      t_submit=time.time())
        self.queue.append(req)
        return req.uid

    # ---- admission / eviction policy ---------------------------------

    @staticmethod
    def _order(req: Request):
        """v2 queue order: priority first, then rotate evicted/preempted
        requests behind same-priority waiters, then submission order."""
        return (-req.priority, req.evictions, req.t_submit, req.uid)

    def _mark_admitted(self, req: Request, now: float) -> None:
        if req._t_evict is not None:
            req.evicted_s += now - req._t_evict
            req._t_evict = None
        req.t_admit = now
        req.tokens_since_admit = 0

    def _evict(self, req: Request, now: float) -> None:
        """Displace ``req`` from the live set and requeue it.  On a
        paged engine this SUSPENDS: the request's KV pages detach into
        a handle (``Request._kv_handle``) and re-admission is a table
        re-attach — no recompute.  Otherwise (or after the handle is
        stripped under page pressure) the session is released outright
        and re-admission re-prefills prompt+output, which rebuilds KV
        bitwise equal to the state just dropped — either way the
        displacement is token-invisible (DESIGN.md §12)."""
        self.live.remove(req)
        if self.engine.has_session(req.uid):
            if getattr(self.engine, "can_suspend", lambda: False)():
                req._kv_handle = self.engine.suspend(req.uid)
            else:
                self.engine.evict(req.uid)
        req.evictions += 1
        req._t_evict = now
        self.queue.append(req)

    def _lifetime_pages(self, req: Request) -> int:
        """Worst-case page commitment: the pages ``req`` will hold once
        fully decoded.  Admission against lifetime commitments (not
        current holdings) guarantees mid-round ``reserve`` can never
        exhaust a fixed page budget."""
        return self.engine.request_pages(len(req.prompt) + req.max_new)

    def _pick_victim(self, below_priority: int, protect: set):
        """Lowest-priority live request strictly below
        ``below_priority`` (never admitted this step), shortest prefix
        first — the cheapest re-prefill loses its slot."""
        cands = [r for r in self.live
                 if r.priority < below_priority and id(r) not in protect]
        if not cands:
            return None
        return min(cands, key=lambda r: (r.priority,
                                         len(r.prompt) + len(r.output),
                                         r.uid))

    def _admit_v2(self, now: float) -> list:
        page_state = self.engine.page_state()
        fixed = bool(page_state and page_state.get("fixed"))
        newly: list = []
        protect: set = set()
        while self.queue:
            cand = min(self.queue, key=self._order)
            blocked_by_pages = False
            if fixed:
                # Pages spoken for: live requests count their LIFETIME
                # commitment (they grow every round, worst case to full
                # decode); suspended queue entries count their handle's
                # actual holdings (detached chains never grow — growth
                # re-enters through this same check at resume, when the
                # resumed request's lifetime is charged as ``need``).
                committed = sum(self._lifetime_pages(r) for r in self.live)
                committed += sum(self.engine.handle_pages(q._kv_handle)
                                 for q in self.queue
                                 if q._kv_handle is not None and q is not cand)
                need = self._lifetime_pages(cand)
                if need > page_state["total"]:
                    raise ValueError(
                        f"request uid={cand.uid} needs {need} pages but "
                        f"the pool only has {page_state['total']}")
                blocked_by_pages = committed + need > page_state["total"]
            if len(self.live) >= self.max_batch or blocked_by_pages:
                # Page pressure reclaims from suspended holders first:
                # stripping the worst-ranked handle behind ``cand``
                # frees pages without touching the live set (the holder
                # re-admits later via re-prefill).  Handles ranked
                # AHEAD of cand are never stripped — those requests
                # resume before cand anyway.
                if blocked_by_pages and len(self.live) < self.max_batch:
                    holders = [q for q in self.queue
                               if q._kv_handle is not None and q is not cand
                               and self._order(q) > self._order(cand)]
                    if holders:
                        worst = max(holders, key=self._order)
                        self.engine.drop_handle(worst._kv_handle)
                        worst._kv_handle = None
                        self.metrics.evictions += 1
                        continue
                victim = self._pick_victim(cand.priority, protect)
                if victim is None:
                    break
                self._evict(victim, now)
                self.metrics.evictions += 1
                continue
            self.queue.remove(cand)
            self.live.append(cand)
            protect.add(id(cand))
            self._mark_admitted(cand, now)
            if cand._kv_handle is not None:
                # Resume from the suspend handle: session re-binds to a
                # free slot host-side, KV already resident — the request
                # advances THIS round (no prefill to overlap), which is
                # token-invisible because randomness is (uid, blocks)-
                # keyed, never round-keyed.
                self.engine.resume(cand.uid, cand._kv_handle)
                cand._kv_handle = None
            else:
                newly.append(cand)
        return newly

    def _preempt(self, now: float) -> None:
        """Fairness rotation: while requests wait in the queue, evict
        live requests that have emitted ``preempt_tokens`` or more
        tokens since their last admission.  Their incremented eviction
        count sorts them behind same-priority waiters, so slots rotate
        instead of ping-ponging."""
        if not self.preempt_tokens or not self.queue:
            return
        for req in list(self.live):
            if req.done or req.tokens_since_admit < self.preempt_tokens:
                continue
            # Only preempt when some waiter would actually outrank the
            # displaced request in the admission order — otherwise the
            # eviction is pure churn: the same request re-admits
            # immediately and pays a re-prefill for nothing (a high-
            # priority request is never preempted for low-priority
            # waiters).
            displaced = (-req.priority, req.evictions + 1,
                         req.t_submit, req.uid)
            if not any(self._order(q) < displaced for q in self.queue):
                continue
            self._evict(req, now)
            self.metrics.preemptions += 1

    def _admit(self) -> list:
        """Move queued requests into the live set; returns the newly
        admitted requests."""
        now = time.time()
        if self.policy == "v2":
            self._preempt(now)
            return self._admit_v2(now)
        newly = []
        while self.queue and len(self.live) < self.max_batch:
            req = self.queue.popleft()
            self.live.append(req)
            newly.append(req)
            self._mark_admitted(req, now)
        return newly

    def _required_buf(self, req: Request) -> int:
        return len(req.prompt) + req.max_new + self.engine.cfg.draft_len + 2

    def step(self, key: jax.Array) -> list:
        """Advance every live request by one speculative block.  Returns
        requests that finished this round.

        Under kv_fused with bucketed admission, requests admitted THIS
        step only prefill (overlapped with the round advancing the
        previously admitted requests, DESIGN.md §9) and start emitting
        tokens next step.  Round-alignment differences between modes
        are token-invisible because per-request randomness depends only
        on (uid, blocks) — callers comparing admission policies must
        pass the same ``key`` every step, as ``run()`` does."""
        t0 = time.perf_counter()
        newly = self._admit()
        if not self.live:
            return []
        self._buf_len = max([self._buf_len]
                            + [self._required_buf(r) for r in self.live])
        overlap = (self.cache_mode == "kv_fused"
                   and self.admission == "bucketed")
        new_ids = {id(r) for r in newly}
        advancing = [r for r in self.live if id(r) not in new_ids] \
            if overlap else self.live
        # Nested folds: a flat uid * C + blocks encoding collides across
        # requests once blocks reaches C (see module docstring).
        subs = [jax.random.fold_in(jax.random.fold_in(key, r.uid), r.blocks)
                for r in advancing]
        fw0 = self.engine.num_target_forwards
        ds0 = getattr(self.engine, "num_draft_syncs", 0)
        if overlap:
            # The overlap path skips full-prefix assembly (the engine
            # serves from cached state) but still hands over each
            # request's last emitted token so the engine can enforce
            # the prefix-tail == pending contract loudly.
            tails = [int(r.output[-1]) if r.output else int(r.prompt[-1])
                     for r in advancing]
            # Admission prefixes carry prompt+output: a re-admitted
            # (evicted) request re-prefills everything it has emitted
            # so far, rebuilding KV bitwise equal to the state it lost.
            # For fresh requests output is empty and this is the prompt.
            outs = self.engine.round_with_admission(
                subs, [r.uid for r in advancing],
                [(r.uid, np.concatenate([r.prompt,
                                         np.asarray(r.output, np.int32)]))
                 for r in newly], self._buf_len,
                tails=tails)
        else:
            prefixes = [np.concatenate([r.prompt,
                                        np.asarray(r.output, np.int32)])
                        for r in advancing]
            if self.cache_mode in ("kv", "kv_fused"):
                outs = self.engine.gen_blocks(
                    subs, prefixes, self._buf_len,
                    uids=[r.uid for r in advancing],
                    fused=self.cache_mode == "kv_fused",
                    admission=self.admission)
            elif self.batched:
                outs = self.engine.gen_blocks(subs, prefixes, self._buf_len)
            else:
                outs = [self.engine.gen_block(sub, prefix, self._buf_len)
                        for sub, prefix in zip(subs, prefixes)]
        if advancing:
            self.metrics.rounds += 1
        self.metrics.target_forwards += self.engine.num_target_forwards - fw0
        self.metrics.draft_syncs += (
            getattr(self.engine, "num_draft_syncs", 0) - ds0)

        finished = []
        t_commit = time.time()
        for req, out in zip(advancing, outs):
            # Emit only up to max_new: the block may overshoot on its
            # last round, and streamed tokens / timestamps must match
            # the final (trimmed) output exactly.
            emit = list(out.new_tokens)[:req.max_new - len(req.output)]
            req.output.extend(emit)
            req.blocks += 1
            req.accepted += out.accepted
            req.tokens_since_admit += len(emit)
            self.metrics.host_syncs += out.verify_syncs
            if req.t_first is None:
                req.t_first = t_commit
            for tok in emit:
                req.token_times.append(t_commit)
                if req.on_token is not None:
                    req.on_token(req.uid, int(tok))
            if req.done:
                req.t_done = t_commit
                finished.append(req)
        for req in finished:
            self.live.remove(req)
            if self.cache_mode in ("kv", "kv_fused"):
                self.engine.release(req.uid)
            self.metrics.completed += 1
            self.metrics.total_tokens += len(req.output)
            self.metrics.total_blocks += req.blocks
        self.metrics.wall_s += time.perf_counter() - t0
        return finished

    def run(self, key: jax.Array) -> list:
        """Drain the queue; returns all completed requests in finish order.
        Wall time accrues inside ``step()`` (shared with direct-step
        callers), so this loop adds no timing of its own.  The SAME key
        feeds every round — per-request streams are (uid, blocks)-keyed
        (module docstring), so which round a block lands in never
        changes its randomness."""
        done = []
        while self.queue or self.live:
            done.extend(self.step(key))
        return done
