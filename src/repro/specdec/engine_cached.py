"""KV-cached speculative decoding engine (production path, dense family).

The reference engine (engine.py) re-scores the full prefix each block —
simple and family-agnostic but O(T^2) per sequence.  This engine keeps
persistent KV caches for target and drafter in a slot-based cache arena
(``models/cache_pool.py``) and advances ALL live requests at once with
the slot-aware multi-token ``verify_step_slots`` (DESIGN.md §7):

  per round: drafter: L ``decode_step_slots`` sweeps over the whole
             arena (drafts x slots ride the batch dim)
             target:  ONE ``verify_step_slots`` over every live
             request's (pending token + L drafts)
             fused block verification on shared uniforms (Alg. 2,
             block_verify.py — same dispatcher as the reference engine)
             cache rollback = arena-wide surviving-row replication

Cache rollback correctness: row k* survived steps 1..a, so its cache
slots [pos, pos+a] hold exactly [pending, Y_1..Y_a]; replicating row k*
into all of the slot's rows and rewinding pos to pos+a+1 leaves every
row's cache equal to the accepted prefix.  The bonus/residual token
Y_{a+1} becomes the next block's pending token (its KV enters the cache
when scored).  Row selection contract: when a == 0 every row's slot[pos]
(the shared pending token) is identical, so row 0 is valid; when a > 0
at least one row MUST be active (``_select_rollback_row`` asserts this
invariant instead of letting ``argmax`` silently pick a dead row 0).

Host-sync accounting (DESIGN.md §7.3): ``GenerationStats.host_syncs``
counts every device->host transfer the verification path performs.  The
fused verifier's single ``device_get`` already lands ``active`` on the
host, so rollback row selection is sync-free; per-slot positions are
tracked host-side by the pool, so the former ``int(cache["pos"])`` sync
no longer exists.  Draft-token materialization (one transfer per draft
step, shared with the reference engine) is reported separately as
``draft_syncs`` on the block outcome.

Serving contract: ``gen_block`` / ``gen_blocks`` match the reference
engine's scheduler API (subs, prefixes, buf_len), extended with ``uids``
so the scheduler's ``cache_mode="kv"`` path can pin each request to a
pool slot across rounds (``admit`` at first sight, ``release`` on
completion).  Without uids each call admits and releases an ephemeral
slot — correct, but it re-prefills per block.

``gen_blocks(..., fused=True)`` (the scheduler's ``cache_mode=
"kv_fused"``) replaces the host-driven round above with ONE jitted
device program (DESIGN.md §8): the L-step drafter sweep runs as a
``lax.scan`` with drafted tokens staying device-resident, the stacked
verify, batched Algorithm-2 verification, surviving-row selection,
arena-wide rollback, and the residual drafter catch-up all execute in
the same dispatch with donated cache buffers, and the only
device->host transfer per round is the packed result fetch
(``draft_syncs == 0``, one ``host_sync`` per round).  Token streams are
bit-identical to the host-driven path for every strategy and device
verifier backend.

Admission (DESIGN.md §9): ``admit_batch`` drains an admission wave into
power-of-two length buckets and issues ONE stacked ``prefill_slots``
dispatch per bucket per model — prompts land directly in their arena
rows on device (no temporary cache, no host scatter), rows outside the
wave are write-masked, bucket padding rides the §9 dead-zone argument,
and prompts longer than the largest bucket chunk through repeated
calls, so compile count is bounded by the bucket set rather than by
observed prompt lengths.  ``round_with_admission`` additionally
OVERLAPS admission with decoding: the fused round is dispatched first,
the admission prefills are dispatched against its output arenas, and
only then does the host block on the round's packed fetch — the
admitted sessions join the live set next round.  Both admission paths
produce bit-identical caches to per-request ``admit``
(tests/test_admission.py); ``batched_admission=False`` keeps the
per-request path for reference benchmarking.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (
    CachePool,
    PagedCachePool,
    decode_step_slots,
    decode_step_slots_paged,
    init_cache,
    prefill,
    prefill_slots,
    prefill_slots_paged,
    verify_step_slots,
    verify_step_slots_paged,
)
from repro.models import paged as paged_kv
from repro.serving.guard import check_packed
from repro.specdec import verify as V
from repro.specdec.block_verify import (
    RS_STRATEGIES,
    block_verify_batched,
    run_block_verify,
)
from repro.specdec.engine import (
    BlockOutcome,
    GenerationStats,
    SpecDecConfig,
    block_randomness,
    probs_from_logits,
)


_MIN_BUCKET = 16


def _max_bucket(buf_len: int) -> int:
    """Largest admission bucket: the largest power of two <= buf_len
    (floored at _MIN_BUCKET for tiny test arenas — oversized chunks are
    safe, their pad writes drop at the buffer edge)."""
    b = _MIN_BUCKET
    while b * 2 <= buf_len:
        b *= 2
    return b


def _bucket_plan(n: int, max_bucket: int) -> list:
    """Chunk an n-token prefill into the power-of-two bucket set:
    ``[(offset, length, bucket), ...]``.  Full ``max_bucket`` chunks
    first, then the remainder in the smallest bucket that holds it —
    so the set of compiled prefill shapes is the bucket set, not the
    set of observed prompt lengths (DESIGN.md §9)."""
    chunks = []
    off = 0
    while n - off > max_bucket:
        chunks.append((off, max_bucket, max_bucket))
        off += max_bucket
    rem = n - off
    if rem > 0:
        bucket = _MIN_BUCKET
        while bucket < rem:
            bucket *= 2
        chunks.append((off, rem, bucket))
    return chunks


def _select_rollback_row(active: np.ndarray, num_accepted: int) -> int:
    """Surviving draft row for cache rollback.

    With a == 0 no draft row was accepted: every row's cache agrees on
    the only live position (the shared pending token), so row 0 is
    correct by symmetry.  With a > 0 an accepted path exists and the
    final active mask must contain it — an all-False mask here means the
    verifier and engine disagree about the block, which would silently
    roll the cache back to a rejected row; fail loudly instead.
    """
    active = np.asarray(active)
    if num_accepted <= 0:
        return 0
    hits = np.flatnonzero(active)
    if hits.size == 0:
        raise AssertionError(
            f"rollback invariant violated: num_accepted={num_accepted} "
            "but no draft row is active")
    return int(hits[0])


@dataclasses.dataclass
class _Session:
    """Pool-resident decode state for one request."""
    uid: int
    slot: int
    pending: int                 # last emitted token, not yet in cache


class CachedSpecDecEngine:
    """Multi-request speculative decoding with persistent KV caches.
    Dense-family target and drafter (the paper-scale pair); all six
    verification strategies route through the shared block verifier."""

    def __init__(self, target: tuple, drafter: tuple, cfg: SpecDecConfig,
                 pool_slots: int = 1, batched_admission: bool = True,
                 pool_pages: Optional[int] = None):
        self.t_params, self.t_cfg = target
        self.d_params, self.d_cfg = drafter
        assert self.t_cfg.family == "dense" and self.d_cfg.family == "dense"
        # One drafter model and one draft temperature: the cached draft
        # sweep scores every lane with cfg.temps[0], so heterogeneous
        # temps would silently diverge from the reference engine's
        # per-column path instead of staying bit-identical — refuse them.
        assert len(set(cfg.temps)) == 1, (
            "CachedSpecDecEngine requires homogeneous draft temperatures; "
            "use the reference SpecDecEngine for the diverse-drafts setup")
        self.cfg = cfg
        self.vocab = self.t_cfg.vocab_size
        self.pool_slots = pool_slots
        # Physical page budget for a paged pool (DESIGN.md §12): None
        # auto-grows (starts at contiguous-equivalent capacity, doubles
        # on demand); an int is a HARD budget — reservation past it
        # raises PagePoolExhausted, and the v2 scheduler uses the
        # ``page_state``/``request_pages`` accounting below to evict
        # before ever hitting it.  Ignored for contiguous pools.
        self.pool_pages = pool_pages
        self.pool: Optional[CachePool] = None
        self._sessions: dict = {}
        # Quantized serving (DESIGN.md §11): W8A8 target weights are used
        # ONLY by the verify matmuls — admission prefill keeps the f32
        # tree (prompt KV quality sets the whole session's context) and
        # the drafter stays f32 (it is already the small model).  The
        # KV arenas quantize pool-wide via CachePool(quant=True).
        self._t_verify_params = self.t_params
        if cfg.quant:
            from repro.serving.quant import quantize_params
            self._t_verify_params = quantize_params(self.t_params)
        self._d_step = jax.jit(
            lambda p, t, c, pos: decode_step_slots(
                p, self.d_cfg, t, c, pos, use_kernel=cfg.decode_kernel,
                interpret=cfg.pallas_interpret))
        self._t_verify = jax.jit(
            lambda p, t, c, pos: verify_step_slots(p, self.t_cfg, t, c, pos))
        # Fused round program (built lazily once the pool geometry is
        # known; rebuilt when buf_len grows — the paged program closes
        # over the view length, DESIGN.md §8/§12).
        self._fused_round = None
        self._fused_round_buf = None
        # Paged model-call jits, keyed by (kind, buf_len): the gathered
        # view length is a compile-time shape, so each buffer growth
        # compiles a fresh entry (exactly when the contiguous path
        # would retrace on its grown arena shapes).
        self._paged_jits: dict = {}
        # Persistent contiguous view for the paged kv_fused path (§12):
        # the fused round runs the SAME contiguous program in both
        # modes, operating on this gathered working set; page storage
        # is cold state, synced per-slot only at events (suspend,
        # resume, admission, mode switch).  ``_view_dirty`` tracks
        # slots whose view rows are newer than their pages.
        self._fused_view: Optional[dict] = None
        self._view_dirty: set = set()
        self._t_prefill = jax.jit(
            lambda p, b, c: prefill(p, self.t_cfg, b, c))
        self._d_prefill = jax.jit(
            lambda p, b, c: prefill(p, self.d_cfg, b, c))
        # Bucketed admission (DESIGN.md §9): stacked arena prefill, one
        # compile per (model, bucket) — per-request ``admit`` compiles
        # per observed prompt length instead.  The input arena is
        # donated like the fused round's (§8 donation contract; CPU
        # backends don't implement donation and would warn).
        self.batched_admission = batched_admission
        donate = (2,) if jax.default_backend() != "cpu" else ()
        self._slot_prefill = {
            "target": jax.jit(
                lambda p, t, c, pos, w: prefill_slots(
                    p, self.t_cfg, t, c, pos, w,
                    use_kernel=cfg.prefill_kernel,
                    interpret=cfg.pallas_interpret),
                donate_argnums=donate),
            "drafter": jax.jit(
                lambda p, t, c, pos, w: prefill_slots(
                    p, self.d_cfg, t, c, pos, w,
                    use_kernel=cfg.prefill_kernel,
                    interpret=cfg.pallas_interpret),
                donate_argnums=donate),
        }
        # Serving instrumentation (read by the scheduler / benchmarks).
        self.num_target_forwards = 0
        self.num_draft_forwards = 0
        # Prefill model dispatches spent on admission: 2 per request on
        # the per-request path, <= 2 x buckets per wave when batched.
        self.num_prefill_dispatches = 0
        # Device->host transfers spent materializing draft tokens (one
        # per draft step per round, shared across all live requests).
        self.num_draft_syncs = 0

    # -- pool / session lifecycle ------------------------------------------
    def _ensure_pool(self, buf_len: int) -> CachePool:
        if self.pool is None:
            cfgs = {"target": self.t_cfg, "drafter": self.d_cfg}
            if self.cfg.paged:
                self.pool = PagedCachePool(
                    cfgs, num_slots=self.pool_slots,
                    rows_per_slot=self.cfg.num_drafts, buf_len=buf_len,
                    quant=self.cfg.quant, page_size=self.cfg.page_size,
                    num_pages=self.pool_pages)
            else:
                self.pool = CachePool(
                    cfgs, num_slots=self.pool_slots,
                    rows_per_slot=self.cfg.num_drafts, buf_len=buf_len,
                    quant=self.cfg.quant)
        else:
            if buf_len > self.pool.buf_len:
                # Growth re-traces the fused program AND reshapes the
                # paged view; commit the view first (the scatter must
                # run against the pre-growth table width).
                self._view_commit()
            self.pool.ensure_buf(buf_len)
        return self.pool

    def _paged_jit(self, kind: str):
        """Jitted paged model call for the pool's CURRENT buf_len.
        ``kind``: "d_step" | "t_verify" | "prefill_target" |
        "prefill_drafter"."""
        bl = self.pool.buf_len
        key = (kind, bl)
        if key in self._paged_jits:
            return self._paged_jits[key]
        cfg = self.cfg
        if kind == "d_step":
            fn = jax.jit(
                lambda p, t, pg, tb, pos: decode_step_slots_paged(
                    p, self.d_cfg, t, pg, tb, pos, buf_len=bl,
                    use_kernel=cfg.decode_kernel,
                    interpret=cfg.pallas_interpret))
        elif kind == "t_verify":
            fn = jax.jit(
                lambda p, t, pg, tb, pos: verify_step_slots_paged(
                    p, self.t_cfg, t, pg, tb, pos, buf_len=bl))
        else:
            mcfg = self.t_cfg if kind == "prefill_target" else self.d_cfg
            donate = (2,) if jax.default_backend() != "cpu" else ()
            fn = jax.jit(
                lambda p, t, pg, tb, pos, w: prefill_slots_paged(
                    p, mcfg, t, pg, tb, pos, w, buf_len=bl,
                    use_kernel=cfg.prefill_kernel,
                    interpret=cfg.pallas_interpret),
                donate_argnums=donate)
        self._paged_jits[key] = fn
        return fn

    # -- paged fused view (§12): pages as cold storage ---------------------
    # The paged kv_fused path never pays per-round gather/scatter.  The
    # first fused round gathers ONE contiguous working set; every later
    # round runs the contiguous program on it (donation-chained, zero
    # paging cost).  Page storage only has to be current when something
    # other than the fused round reads it — a suspend detaching a
    # slot's chains, the host-driven kv path, or buffer growth — so
    # sync is per-slot and event-rate, not per-round.

    def _view_sync(self, slots) -> None:
        """Scatter the listed slots' view rows into page storage (rows
        of every other slot are masked out of the table, so their pages
        are bit-untouched).  One slot at a time: the row-subset shape
        is then always (rows_per_slot, n_lp), so the whole event path
        compiles exactly one scatter program per model — a wave-sized
        subset would compile one program PER WAVE SIZE, and a mid-run
        compile is a ~0.5s stall on the serving clock."""
        if self._fused_view is None:
            return
        pool = self.pool
        for slot in sorted(set(slots) & self._view_dirty):
            rows = pool.rows_of(slot)
            tbl = jnp.asarray(pool.page_table[rows])
            for name in ("target", "drafter"):
                sub = {kk: leaf[:, rows]
                       for kk, leaf in self._fused_view[name].items()}
                pool.update(name, paged_kv.scatter_arena_jit(
                    pool.pages[name], tbl, sub))
            self._view_dirty.discard(slot)

    def _view_refresh(self, slots) -> None:
        """Gather the listed slots' rows from page storage into the
        view (after an admission prefill or a resumed handle's attach
        wrote pages behind the view's back).  Per-slot for the same
        one-compiled-shape reason as ``_view_sync``."""
        if self._fused_view is None:
            return
        pool = self.pool
        for slot in sorted(set(slots)):
            rows = pool.rows_of(slot)
            tbl = jnp.asarray(pool.page_table[rows])
            for name in ("target", "drafter"):
                sub = paged_kv.gather_arena_jit(pool.pages[name], tbl,
                                                buf_len=pool.buf_len)
                self._fused_view[name] = {
                    kk: self._fused_view[name][kk].at[:, rows].set(sub[kk])
                    for kk in sub}
            self._view_dirty.discard(slot)

    def _view_commit(self) -> None:
        """Write every dirty slot back to pages and drop the view —
        the full sync a mode switch or buffer growth needs."""
        if self._fused_view is not None:
            self._view_sync(set(self._view_dirty))
            self._fused_view = None
        self._view_dirty.clear()

    # -- page accounting (the v2 scheduler's capacity oracle, §12) ---------
    def has_session(self, uid) -> bool:
        return uid in self._sessions

    def evict(self, uid) -> None:
        """Evict a live session mid-generation: drop the session and
        return its slot (and, paged, its pages) to the pool.  The caller
        re-admits later with the full ``prompt + output`` prefix —
        bit-identical resumption, because re-prefilled KV is bitwise
        equal to decode-built KV and per-request randomness depends only
        on (uid, blocks), never on which round a block ran in."""
        self.release(uid)

    def can_suspend(self) -> bool:
        """Whether preemption can keep KV resident (paged pools only —
        a contiguous slot's KV dies with the slot)."""
        return bool(self.cfg.paged)

    def suspend(self, uid) -> dict:
        """Preempt WITHOUT forfeiting KV: pop the session and detach
        its page chains.  The returned handle owns the pages; the slot
        frees for another request, and ``resume`` re-binds the chains
        to any free slot with a host table rewrite — no prefill, no
        recompute.  Bit-identity is trivial here: the resumed state is
        the SAME device bytes the request left behind."""
        sess = self._sessions.pop(uid)
        # The handle's pages must hold the slot's CURRENT KV; under the
        # fused view they may be stale (pages are cold storage), so
        # flush this one slot's rows first — the only per-suspend cost.
        self._view_sync({sess.slot})
        handle = self.pool.detach(sess.slot)
        handle["pending"] = sess.pending
        return handle

    def resume(self, uid, handle: dict) -> int:
        """Re-admit a suspended request from its handle."""
        assert uid not in self._sessions
        slot = self.pool.alloc()
        self.pool.attach(slot, handle)
        self._view_refresh({slot})
        self._sessions[uid] = _Session(uid=uid, slot=slot,
                                       pending=int(handle["pending"]))
        return slot

    def handle_pages(self, handle: dict) -> int:
        """Physical pages a suspend handle holds."""
        return int(handle["chain_len"]) * self.pool.rows_per_slot

    def drop_handle(self, handle: dict) -> None:
        """Demote a suspended request to hard-evicted: forfeit its
        pages (it re-admits via re-prefill like any evicted request)."""
        self.pool.release_handle(handle)

    # -- fault recovery + degradation ladder (DESIGN.md §13) ---------------
    def discard_round_state(self, scrub: bool = False) -> None:
        """Drop every piece of round-scoped device state after a
        guarded fault, leaving the pool in the host-authoritative state
        a fresh admission wave expects: the fused view (which may hold
        an aborted round's in-flight arenas) and the lazily-mirrored
        device positions/page table.  Callers displace every session
        first — the scheduler evicts or suspends all live requests
        before discarding, so nothing references the dropped state.

        ``scrub=True`` additionally zeroes the KV storage itself — the
        NaN-poisoning recovery.  Finite garbage in dead regions is
        masked out of every attention read, but NaN garbage is not
        (``0 * NaN = NaN`` in the masked weight sum), so arenas that
        may hold poisoned bytes are rebuilt rather than reused."""
        assert not self._sessions, \
            "discard_round_state with live sessions; displace them first"
        self._fused_view = None
        self._view_dirty.clear()
        if self.pool is not None:
            self.pool.drop_device_mirrors()
            if scrub:
                self.pool.scrub()

    def set_verifier_backend(self, backend: str) -> None:
        """Degradation-ladder rung: swap the block-verification backend
        in place (pallas -> xla in practice).  Token-invisible — the
        backends are exact-equality oracles of one another
        (tests/test_block_verify.py asserts array_equal across them).
        The fused round program closes over the config, so it rebuilds
        lazily on the next round."""
        if backend == self.cfg.verifier_backend:
            return
        self.cfg = dataclasses.replace(self.cfg, verifier_backend=backend)
        self._fused_round = None

    def dequantize_verify(self) -> None:
        """Degradation-ladder rung quant -> f32: swap the W8A8 verify
        weights back to the f32 tree.  The KV arenas keep their int8
        STORAGE format (rebuilding the pool mid-serve would drop every
        live session); only the verify matmuls change precision.  Note
        this rung is acceptance-equivalent, not bit-identical — the
        chaos bit-identity gate runs unquantized configs."""
        self._t_verify_params = self.t_params
        self._verify_dequantized = True

    def page_state(self) -> Optional[dict]:
        """{free, total, fixed} physical-page accounting, or None when
        the engine is not paged.  Before the pool exists the whole
        budget is free."""
        if not self.cfg.paged:
            return None
        if self.pool is not None:
            return {"free": self.pool.free_pages,
                    "total": self.pool.num_pages,
                    "fixed": self.pool.fixed_budget}
        if self.pool_pages is None:
            return {"free": None, "total": None, "fixed": False}
        return {"free": self.pool_pages, "total": self.pool_pages,
                "fixed": True}

    def request_pages(self, prefix_len: int) -> int:
        """Pages a request at prefix length ``prefix_len`` holds AFTER
        its next speculative round: every round reserves through
        ``pos + L + 1`` positions across its K lanes, so this is the
        number the scheduler must budget to admit (or keep) it."""
        per_row = -(-(prefix_len + self.cfg.draft_len + 1)
                    // self.cfg.page_size)
        return per_row * self.cfg.num_drafts

    def held_pages(self, uid) -> int:
        if self.pool is None or uid not in self._sessions:
            return 0
        return self.pool.held_pages(self._sessions[uid].slot)

    def admit(self, uid: int, prompt: np.ndarray, buf_len: int) -> int:
        """Per-request admission (the reference path): allocate a slot
        and prefill both models with the prompt minus its last token
        (which becomes the first pending token) via a temporary K-row
        cache and a host-driven row scatter.  ``admit_batch`` is the
        production path — bit-identical caches, bucketed dispatches."""
        assert uid not in self._sessions
        prompt = np.asarray(prompt, np.int32)
        assert len(prompt) >= 1
        pool = self._ensure_pool(buf_len)
        slot = pool.alloc()
        K = self.cfg.num_drafts
        toks = jnp.broadcast_to(jnp.asarray(prompt[None, :-1]),
                                (K, len(prompt) - 1))
        for name, params, fn in (("target", self.t_params, self._t_prefill),
                                 ("drafter", self.d_params, self._d_prefill)):
            cache = init_cache(self.t_cfg if name == "target" else self.d_cfg,
                               K, pool.buf_len)
            _, cache = fn(params, {"tokens": toks}, cache)
            pool.write_prefill(name, slot, cache, pos=len(prompt) - 1)
            self.num_prefill_dispatches += 1
        self._view_refresh({slot})
        self._sessions[uid] = _Session(uid=uid, slot=slot,
                                       pending=int(prompt[-1]))
        return slot

    def admit_batch(self, pairs, buf_len: int) -> None:
        """Bucketed batched admission (DESIGN.md §9): admit every
        ``(uid, prompt)`` in ``pairs`` with prompt KV written straight
        into the pool arenas on device.

        The wave's prefills drain into power-of-two length buckets
        (``_bucket_plan``); each (chunk round, bucket) group is ONE
        stacked ``prefill_slots`` dispatch per model over the whole
        arena — rows outside the group are write-masked — so a wave
        costs at most ``2 x buckets`` dispatches per chunk round instead
        of ``2 x requests``, and the compiled shape set is the bucket
        set.  Chunk c+1 of a prompt attends chunk c's KV already in the
        arena, which is what makes repeated calls equal one long
        prefill."""
        pairs = [(uid, np.asarray(p, np.int32)) for uid, p in pairs]
        if not pairs:
            return
        pool = self._ensure_pool(buf_len)
        paged = isinstance(pool, PagedCachePool)
        rows_n = pool.num_slots * self.cfg.num_drafts
        max_bucket = _max_bucket(pool.buf_len)
        plans = []
        for uid, prompt in pairs:
            assert uid not in self._sessions
            assert len(prompt) >= 1
            slot = pool.alloc()
            self._sessions[uid] = _Session(uid=uid, slot=slot,
                                           pending=int(prompt[-1]))
            if paged:
                # Reserve the whole prompt's chain up front (host-side
                # table bookkeeping only) so every chunk's scattered
                # writes land in mapped pages.
                pool.reserve(slot, len(prompt) - 1)
            plans.append((slot, prompt[:-1],
                          _bucket_plan(len(prompt) - 1, max_bucket)))
        params = {"target": self.t_params, "drafter": self.d_params}
        # Paged + fused view live (§12): prefill straight INTO the view
        # with the contiguous ``prefill_slots`` program — the admitted
        # slots become dirty (pages get their content only if they
        # later suspend), and the wave pays zero gather/refresh.
        # Without a view (first wave, or the host-driven kv path) the
        # prefills scatter through the page table as before.
        use_view = paged and self._fused_view is not None
        for c in range(max(len(p[2]) for p in plans)):
            groups = {}
            for slot, toks, chunks in plans:
                if c < len(chunks):
                    groups.setdefault(chunks[c][2], []).append(
                        (slot, toks, chunks[c]))
            for bucket in sorted(groups):
                tok = np.zeros((rows_n, bucket), np.int32)
                pos = np.zeros((rows_n,), np.int32)
                write = np.zeros((rows_n,), bool)
                for slot, toks, (off, ln, _) in groups[bucket]:
                    rr = pool.rows_of(slot)
                    tok[rr, :ln] = toks[off:off + ln]
                    pos[rr] = off
                    write[rr] = True
                tok_d, pos_d, write_d = (jnp.asarray(tok), jnp.asarray(pos),
                                         jnp.asarray(write))
                for name in ("target", "drafter"):
                    # Install each chunk's output arena immediately —
                    # the input buffer is donated, so pool.caches must
                    # never be left pointing at it (a mid-wave failure
                    # would otherwise corrupt the pool).
                    if use_view:
                        self._fused_view[name] = self._slot_prefill[name](
                            params[name], tok_d, self._fused_view[name],
                            pos_d, write_d)
                    elif paged:
                        pool.update(name, self._paged_jit(
                            "prefill_" + name)(
                                params[name], tok_d, pool.pages[name],
                                pool.pt_device(), pos_d, write_d))
                    else:
                        pool.update(name, self._slot_prefill[name](
                            params[name], tok_d, pool.caches[name], pos_d,
                            write_d))
                    self.num_prefill_dispatches += 1
        for slot, toks, _ in plans:
            pool.set_pos(slot, len(toks))
        if use_view:
            self._view_dirty.update(slot for slot, _, _ in plans)
        elif paged:
            # The wave's prefills wrote PAGES behind an absent view;
            # nothing to pull (the next fused round's entry gather or
            # the kv path's ops read pages directly).
            pass

    def release(self, uid: int) -> None:
        sess = self._sessions.pop(uid)
        self._view_dirty.discard(sess.slot)
        self.pool.release(sess.slot)

    # -- the batched cached block ------------------------------------------
    def _block_randomness(self, sub: jax.Array):
        # Shared with the reference engine so both see the same uniform
        # sheet (the RNG contract of DESIGN.md §3.2).
        return block_randomness(sub, self.cfg.draft_len,
                                self.cfg.num_drafts, self.vocab)

    def _block_cached(self, subs: Sequence[jax.Array],
                      uids: Sequence[int]) -> list:
        """Advance every listed session one speculative block: one drafter
        decode sweep (x L) and ONE stacked verify_step over the whole
        arena, then per-request fused verification + arena rollback."""
        cfg = self.cfg
        pool = self.pool
        K, Lr, N = cfg.num_drafts, cfg.draft_len, self.vocab
        S = pool.num_slots
        sessions = [self._sessions[u] for u in uids]
        r_n = len(sessions)
        need_probs = cfg.strategy in RS_STRATEGIES

        rand = [self._block_randomness(s) for s in subs]
        log_u_all = jnp.stack([lu for lu, _ in rand])     # (R, L+1, K, N)

        live_rows = np.concatenate([pool.rows_of(s.slot) for s in sessions])
        base_pos = pool.pos.copy()                        # (S,) host
        row_pos0 = pool.row_positions()                   # (S*K,) host
        # The verify chunk writes positions [pos, pos + L]; the arenas are
        # non-ring, so running past the buffer must fail loudly here
        # rather than silently wrap/clamp the KV writes.  Callers size
        # buf_len as len(prompt) + max_new + L + 2 (scheduler contract).
        hi = max(base_pos[s.slot] for s in sessions) + Lr + 1
        assert hi <= pool.buf_len, (
            f"speculative block would write through position {hi - 1} but "
            f"the cache arena holds {pool.buf_len}; pass a larger buf_len")
        paged = isinstance(pool, PagedCachePool)
        table = None
        if paged:
            # The host-driven path's ops read/write page storage
            # directly; if fused rounds left a newer view, commit it
            # (mixing modes on one engine stays bit-exact).
            self._view_commit()
            # Extend every advancing slot's chain through the round's
            # write horizon (verify writes [pos, pos + L], catch-up
            # writes at pos + L) before any device work is dispatched.
            for sess in sessions:
                pool.reserve(sess.slot, int(base_pos[sess.slot]) + Lr + 1)
            table = pool.pt_device()

        # --- drafts: L arena decode sweeps, live rows advance -------------
        cur = np.zeros((S * K, 1), np.int32)
        for sess in sessions:
            cur[pool.rows_of(sess.slot)] = sess.pending
        d_tokens = np.zeros((r_n, K, Lr), np.int32)
        prob_steps = []
        d_cache = pool.pages["drafter"] if paged else pool.caches["drafter"]
        draft_syncs = 0
        for j in range(Lr):
            if paged:
                logits, d_cache = self._paged_jit("d_step")(
                    self.d_params, jnp.asarray(cur), d_cache, table,
                    jnp.asarray(row_pos0 + j))
            else:
                logits, d_cache = self._d_step(
                    self.d_params, jnp.asarray(cur), d_cache,
                    jnp.asarray(row_pos0 + j))
            self.num_draft_forwards += 1
            live = logits[jnp.asarray(live_rows)]
            p_all = probs_from_logits(live, cfg.temps[0], cfg.top_k, N)
            tok = V.draft_token_from_uniforms(
                log_u_all[:, j].reshape(r_n * K, N), p_all)
            tk = np.asarray(tok).reshape(r_n, K)   # 1 transfer / draft step
            draft_syncs += 1
            d_tokens[:, :, j] = tk
            cur = np.zeros((S * K, 1), np.int32)
            for r, sess in enumerate(sessions):
                cur[pool.rows_of(sess.slot), 0] = tk[r]
            if need_probs:
                prob_steps.append(p_all)
        pool.update("drafter", d_cache)
        d_probs = None
        if need_probs:
            d_probs = jnp.stack(prob_steps).reshape(
                Lr, r_n, K, N).transpose(1, 2, 0, 3)

        # --- target: ONE stacked verify chunk over the arena --------------
        chunk = np.zeros((S * K, Lr + 1), np.int32)
        for r, sess in enumerate(sessions):
            chunk[pool.rows_of(sess.slot)] = np.concatenate(
                [np.full((K, 1), sess.pending, np.int32), d_tokens[r]],
                axis=1)
        if paged:
            t_logits, t_cache = self._paged_jit("t_verify")(
                self._t_verify_params, jnp.asarray(chunk),
                pool.pages["target"], table, jnp.asarray(row_pos0))
        else:
            t_logits, t_cache = self._t_verify(
                self._t_verify_params, jnp.asarray(chunk),
                pool.caches["target"], jnp.asarray(row_pos0))
        self.num_target_forwards += 1
        pool.update("target", t_cache)
        q = probs_from_logits(t_logits[jnp.asarray(live_rows)],
                              cfg.target_temp, cfg.top_k, N)
        q = q.reshape(r_n, K, Lr + 1, N)

        # --- fused block verification (Algorithm 2), per request ----------
        outs = []
        row_src = np.arange(S * K)
        full_slots = {}          # slot -> Y_L, for a == L catch-up
        for r, sess in enumerate(sessions):
            hb = run_block_verify(
                log_u_all[r], d_tokens[r],
                None if d_probs is None else d_probs[r], q[r], rand[r][1],
                strategy=cfg.strategy, backend=cfg.verifier_backend,
                interpret=cfg.pallas_interpret)
            a = hb.num_accepted
            # hb.active is already host-side — the fused verifier's single
            # device_get covers it, so selecting the surviving row costs
            # no extra sync (the accounting rule of DESIGN.md §7.3).
            k_star = _select_rollback_row(hb.active, a)
            rows = pool.rows_of(sess.slot)
            row_src[rows] = rows[0] + k_star
            pool.set_pos(sess.slot, base_pos[sess.slot] + 1 + a)
            if a == Lr:
                # Drafter consumed [pending, d_1..d_{L-1}]: on full
                # acceptance its cache is one token short — feed Y_L at
                # position base_pos + L in the post-rollback sweep below.
                full_slots[sess.slot] = hb.new_tokens[Lr - 1]
            sess.pending = hb.new_tokens[-1]
            outs.append(BlockOutcome(new_tokens=hb.new_tokens,
                                     accepted=a,
                                     verify_syncs=hb.host_syncs,
                                     active=hb.active))

        # --- arena rollback: one gather replicates surviving rows ---------
        pool.rollback_rows(row_src)

        if full_slots:
            # One extra drafter sweep catches up fully-accepted slots
            # (write Y_L at base_pos + L).  Every other row decodes a
            # dummy token at its POST-rollback position — exactly where
            # the next block's first sweep writes that row's pending
            # token, so the dummy KV is overwritten before anything can
            # attend to it (free-slot rows are fully overwritten by the
            # admission prefill scatter).
            extra_tokens = np.zeros((S * K, 1), np.int32)
            extra_pos = pool.row_positions()          # post-rollback pos
            for slot, y_l in full_slots.items():
                rows = pool.rows_of(slot)
                extra_tokens[rows, 0] = y_l
                extra_pos[rows] = base_pos[slot] + Lr
            if paged:
                _, d_cache = self._paged_jit("d_step")(
                    self.d_params, jnp.asarray(extra_tokens),
                    pool.pages["drafter"], table,
                    jnp.asarray(extra_pos, np.int32))
            else:
                _, d_cache = self._d_step(
                    self.d_params, jnp.asarray(extra_tokens),
                    pool.caches["drafter"], jnp.asarray(extra_pos, np.int32))
            self.num_draft_forwards += 1
            pool.update("drafter", d_cache)

        self.num_draft_syncs += draft_syncs
        return outs

    # -- the fused single-dispatch round (DESIGN.md §8) ---------------------
    def _build_fused_round(self):
        """Compile the whole speculative round into one jitted program.

        Geometry (S slots x K lanes, L steps) is closed over, so the
        program has fixed shapes regardless of how many requests are
        live — liveness is a data-level (S,) mask, and free slots ride
        along as dead rows exactly as they do in the host-driven round.
        Cache arenas and device positions are DONATED (where the backend
        supports it): callers must install the returned buffers via
        ``CachePool.adopt_round_device`` (then ``refresh_pos_host`` once
        the packed result lands) and never touch the inputs again.
        """
        cfg, t_cfg, d_cfg = self.cfg, self.t_cfg, self.d_cfg
        K, L, N = cfg.num_drafts, cfg.draft_len, self.vocab
        S = self.pool.num_slots
        rows = S * K
        slot_of = jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)
        row_ids = jnp.arange(rows, dtype=jnp.int32)
        need_probs = cfg.strategy in RS_STRATEGIES
        if cfg.verifier_backend == "legacy":
            raise ValueError(
                "fused rounds need a device verifier backend ('xla' or "
                "'pallas'); the 'legacy' host loop cannot run in-program")

        # Paged rounds (§12) run this SAME program: the engine holds a
        # persistent contiguous view of the page pool (gathered once,
        # donation-chained round to round), so the steady-state round
        # pays ZERO paging cost — no table input, no per-round
        # gather/scatter.  Page storage syncs per-slot at events only
        # (suspend/resume/admission); an earlier design gathered and
        # scattered both arenas inside every round and cost ~50% extra
        # wall per round on CPU.  Bit-identity is untouched: the view
        # holds exactly the contiguous arena's bytes on live rows, and
        # dead-row garbage is masked by kv_len like it always was.
        def round_core(t_params, d_params, t_kv, d_kv, pos, pending, live,
                       subs):
            live_row = jnp.repeat(live, K)
            # Rows of slots NOT advancing this round (free, or occupied
            # but unlisted) still ride along as dead rows; they must
            # decode at their own position — the pool zeroes ``pos`` on
            # release, and an occupied slot's garbage writes land at
            # [pos, pos+L], beyond everything its next real round reads
            # (the same safety argument as the host-driven sweep).
            row_pos = jnp.repeat(pos, K)
            # Per-slot shared uniforms + strategy keys, drawn in-program:
            # vmapped jax.random equals its per-lane unbatched draws, so
            # each live slot sees exactly the sheet the host-driven
            # round would hand it (the §3.2 RNG contract).
            log_u, strat_keys = jax.vmap(
                lambda s: block_randomness(s, L, K, N))(subs)

            # --- drafter sweep: L decode steps, tokens device-resident
            cur0 = jnp.where(live_row, jnp.repeat(pending, K),
                             0).astype(jnp.int32)[:, None]

            def dstep(carry, inp):
                cur, dc = carry
                log_u_j, j = inp
                logits, dc = decode_step_slots(
                    d_params, d_cfg, cur, dc, row_pos + j,
                    use_kernel=cfg.decode_kernel,
                    interpret=cfg.pallas_interpret)
                p_all = probs_from_logits(logits, cfg.temps[0], cfg.top_k,
                                          N)
                tok = V.draft_token_from_uniforms(
                    log_u_j.reshape(rows, N), p_all)
                tok = jnp.where(live_row, tok, 0).astype(jnp.int32)
                ys = (tok, p_all) if need_probs else tok
                return (tok[:, None], dc), ys

            xs = (jnp.swapaxes(log_u[:, :L], 0, 1),
                  jnp.arange(L, dtype=jnp.int32))
            (_, d_kv1), ys = jax.lax.scan(dstep, (cur0, dict(d_kv)), xs)
            toks = ys[0] if need_probs else ys            # (L, rows)
            d_tokens = toks.T.reshape(S, K, L)
            d_probs = (ys[1].reshape(L, S, K, N).transpose(1, 2, 0, 3)
                       if need_probs else None)

            # --- target: ONE stacked verify chunk over the arena ------
            chunk = jnp.concatenate([cur0, toks.T], axis=1)
            t_logits, t_kv2 = verify_step_slots(
                t_params, t_cfg, chunk, t_kv, row_pos)
            q = probs_from_logits(t_logits, cfg.target_temp, cfg.top_k,
                                  N).reshape(S, K, L + 1, N)

            # --- Algorithm 2, batched over slots ----------------------
            res = block_verify_batched(
                log_u, d_tokens, d_probs, q, strat_keys,
                strategy=cfg.strategy, backend=cfg.verifier_backend,
                interpret=cfg.pallas_interpret)
            a = jnp.where(live, res.num_accepted, 0)
            # Surviving row: a == 0 -> row 0 (all rows agree on the
            # pending token); a > 0 -> first active row.  The a>0 ⇒
            # some-row-active invariant is re-checked host-side on the
            # packed result, where it can still fail loudly (§7.2).
            k_star = jnp.where(
                a > 0, jnp.argmax(res.active, axis=1).astype(jnp.int32), 0)

            # --- arena rollback: in-program surviving-row gather ------
            surv = slot_of * K + k_star[slot_of]
            row_src = jnp.where(live_row, surv, row_ids)
            t_kv2 = {kk: jnp.take(t_kv2[kk], row_src, axis=1)
                     for kk in t_kv2}
            d_kv2 = {kk: jnp.take(d_kv1[kk], row_src, axis=1)
                     for kk in d_kv1}
            new_pos = jnp.where(live, pos + 1 + a, pos)

            # --- residual drafter catch-up ----------------------------
            # Fully-accepted slots write Y_L at base_pos + L; every
            # other row decodes a dummy token at its post-rollback
            # position, which the next round's first sweep (or the next
            # admission's prefill scatter) overwrites before anything
            # attends it.  Unlike the host-driven round this step is
            # unconditional — a fixed program cannot branch on host
            # data — and the dummy writes are harmless for the same
            # reason they are in the conditional path.
            full = live & (a == L)
            y_l = res.tokens[:, L - 1]
            extra_tok = jnp.where(full[slot_of], y_l[slot_of],
                                  0).astype(jnp.int32)[:, None]
            extra_pos = jnp.where(full, pos + L, new_pos)
            _, d_kv3 = decode_step_slots(
                d_params, d_cfg, extra_tok, d_kv2,
                jnp.repeat(extra_pos, K),
                use_kernel=cfg.decode_kernel,
                interpret=cfg.pallas_interpret)

            packed = {"tokens": res.tokens, "accepted": a,
                      "active": res.active, "pos": new_pos}
            return t_kv2, d_kv3, new_pos, packed

        # Buffer donation (the §8 donation contract).  CPU backends do
        # not implement donation and would warn on every dispatch, so
        # only donate where it is real.
        donate = (2, 3, 4) if jax.default_backend() != "cpu" else ()
        return jax.jit(round_core, donate_argnums=donate)

    def _block_fused(self, subs: Sequence[jax.Array],
                     uids: Sequence[int], admits: Sequence = ()) -> list:
        """Advance every listed session one speculative round as ONE
        device dispatch; the round's only device->host transfer is the
        packed (tokens, accepted, active, pos) fetch.

        ``admits`` are ``(uid, prompt)`` pairs admitted INSIDE the
        round's overlap window (DESIGN.md §9): their bucketed prefill
        dispatches are issued against the round's output arenas after
        the round is in flight but BEFORE the host blocks on the packed
        fetch, so admission costs no extra host round-trip and the
        prompts prefill while the round computes."""
        cfg, pool = self.cfg, self.pool
        K, L, S = cfg.num_drafts, cfg.draft_len, pool.num_slots
        sessions = [self._sessions[u] for u in uids]
        # Same loud non-ring overflow guard as the host-driven round.
        hi = max(pool.pos[s.slot] for s in sessions) + L + 1
        assert hi <= pool.buf_len, (
            f"speculative block would write through position {hi - 1} but "
            f"the cache arena holds {pool.buf_len}; pass a larger buf_len")
        paged = isinstance(pool, PagedCachePool)
        if paged:
            # Host-side table bookkeeping before dispatch: each advancing
            # slot's chain must cover the round's write horizon.
            for sess in sessions:
                pool.reserve(sess.slot, int(pool.pos[sess.slot]) + L + 1)

        live = np.zeros(S, bool)
        pending = np.zeros(S, np.int32)
        # Free slots still need a syntactically valid key for the
        # in-program randomness; their draws are masked garbage.
        sub_rows = [jax.random.PRNGKey(0)] * S
        for sess, sub in zip(sessions, subs):
            live[sess.slot] = True
            pending[sess.slot] = sess.pending
            sub_rows[sess.slot] = sub

        # The program closes over the view length (paged) and is keyed
        # to pool geometry; rebuild when the buffer grows.  (The
        # contiguous program re-traces on grown arena shapes anyway —
        # rebuilding matches cost, old shapes never recur.)
        if self._fused_round is None or self._fused_round_buf != pool.buf_len:
            self._fused_round = self._build_fused_round()
            self._fused_round_buf = pool.buf_len
        if paged:
            # First fused round (or first after a mode switch / buffer
            # growth dropped the view): gather the working set ONCE.
            # Every later round chains on the previous round's output
            # arenas — the same donation flow as the contiguous path.
            if self._fused_view is None:
                pt = pool.pt_device()
                self._fused_view = {
                    name: paged_kv.gather_arena_jit(
                        pool.pages[name], pt, buf_len=pool.buf_len)
                    for name in ("target", "drafter")}
                self._view_dirty.clear()
            arenas = self._fused_view
        else:
            arenas = pool.caches
        t_kv, d_kv, pos_dev, packed = self._fused_round(
            self._t_verify_params, self.d_params,
            arenas["target"], arenas["drafter"],
            pool.pos_device(), jnp.asarray(pending), jnp.asarray(live),
            jnp.stack(sub_rows))
        self.num_draft_forwards += L + 1
        self.num_target_forwards += 1

        # Install the round's device outputs and use the in-flight gap
        # to dispatch this wave's admission prefills (they consume the
        # round's output arenas, so device execution stays ordered).
        if paged:
            self._fused_view = {"target": t_kv, "drafter": d_kv}
            self._view_dirty.update(s.slot for s in sessions)
            pool.adopt_pos_device(pos_dev)
        else:
            pool.adopt_round_device({"target": t_kv, "drafter": d_kv},
                                    pos_dev)
        if admits:
            self.admit_batch(admits, pool.buf_len)

        host = jax.device_get(packed)          # the round's ONE transfer
        pool.refresh_pos_host(host["pos"], [s.slot for s in sessions])
        # Guard the raw fetch (DESIGN.md §13): token range/finiteness,
        # accepted bounds, and the rollback invariant — a NaN-poisoned
        # logit row makes the race argmax emit garbage ids, and this is
        # the last point before that garbage becomes session state.
        check_packed(host, [(s.uid, s.slot) for s in sessions],
                     vocab=self.vocab, draft_len=L)
        outs = []
        for i, sess in enumerate(sessions):
            s = sess.slot
            acc = int(host["accepted"][s])
            active = np.asarray(host["active"][s])
            toks = [int(t) for t in host["tokens"][s][:acc + 1]]
            sess.pending = toks[-1]
            # The packed fetch is one transfer for the WHOLE round;
            # attribute it to the round's first outcome so aggregate
            # accounting reads host_syncs == rounds (§7.3).
            outs.append(BlockOutcome(new_tokens=toks, accepted=acc,
                                     verify_syncs=1 if i == 0 else 0,
                                     active=active))
        return outs

    # -- scheduler contract -------------------------------------------------
    def _admit_wave(self, pairs, buf_len: int,
                    admission: Optional[str] = None) -> None:
        """Admit unseen sessions: one bucketed wave (``admit_batch``) or
        per-request ``admit``.  ``admission`` overrides the engine's
        ``batched_admission`` default per call (the scheduler passes its
        own policy through rather than reconfiguring the engine)."""
        if admission is None:
            admission = "bucketed" if self.batched_admission \
                else "per_request"
        if admission == "bucketed":
            self.admit_batch(pairs, buf_len)
        else:
            for uid, prompt in pairs:
                self.admit(uid, prompt, buf_len)

    def round_with_admission(self, subs: Sequence[jax.Array],
                             uids: Sequence[int], admits: Sequence,
                             buf_len: int,
                             tails: Optional[Sequence[int]] = None) -> list:
        """One kv_fused serving round with overlapped admission (§9):
        grow the pool for the whole wave, dispatch the fused round for
        ``uids`` (the already-admitted sessions), dispatch the bucketed
        admission prefills for ``admits`` while the round runs, and only
        then block on the round's packed fetch.  Admitted sessions
        produce no tokens this round — they join the live set next
        round.  ``tails`` (the caller's last emitted token per uid)
        enforces the prefix-tail == pending contract that the
        prefix-carrying ``gen_blocks`` path checks.  Returns
        ``BlockOutcome``s for ``uids`` only."""
        self._ensure_pool(buf_len)
        if tails is not None:
            for uid, tail in zip(uids, tails):
                sess = self._sessions[uid]
                assert int(tail) == sess.pending, (
                    f"uid {uid}: prefix tail {int(tail)} != cached "
                    f"pending {sess.pending}")
        if not uids:
            self._admit_wave(admits, buf_len, admission="bucketed")
            return []
        return self._block_fused(subs, uids, admits=admits)

    def gen_blocks(self, subs: Sequence[jax.Array],
                   prefixes: Sequence[np.ndarray], buf_len: int,
                   uids: Optional[Sequence[int]] = None,
                   fused: bool = False,
                   admission: Optional[str] = None) -> list:
        """Advance R requests by one speculative block each (the reference
        engine's scheduler contract, DESIGN.md §1).  With ``uids`` the
        engine serves from persistent slots: unseen uids are admitted
        as one bucketed wave (their prefixes prefill straight into the
        pool arenas, §9; ``admission="per_request"`` keeps the reference
        path), known uids continue from their cached state and
        ``prefixes[i]`` only validates the contract (its last token
        must equal the session's pending token).  Without uids, each
        call runs against ephemeral slots.  ``fused=True`` runs the
        round as one device dispatch (§8) — same tokens, 0 draft syncs,
        1 host sync per round."""
        block = self._block_fused if fused else self._block_cached
        if uids is None:
            ephemeral = [object() for _ in prefixes]
            try:
                self._admit_wave(list(zip(ephemeral, prefixes)), buf_len,
                                 admission)
                outs = block(subs, ephemeral)
            finally:
                for uid in ephemeral:
                    if uid in self._sessions:
                        self.release(uid)
            return outs
        self._ensure_pool(buf_len)
        new = []
        for uid, pre in zip(uids, prefixes):
            pre = np.asarray(pre, np.int32)
            if uid not in self._sessions:
                new.append((uid, pre))
            else:
                sess = self._sessions[uid]
                assert int(pre[-1]) == sess.pending, (
                    f"uid {uid}: prefix tail {int(pre[-1])} != cached "
                    f"pending {sess.pending}")
        self._admit_wave(new, buf_len, admission)
        return block(subs, uids)

    def gen_block(self, key: jax.Array, prefix: np.ndarray, buf_len: int,
                  uid=None, fused: bool = False):
        """Single-request speculative block (the R=1 case of gen_blocks)."""
        uids = None if uid is None else [uid]
        return self.gen_blocks([key], [np.asarray(prefix, np.int32)],
                               buf_len, uids=uids, fused=fused)[0]

    # -- public API ---------------------------------------------------------
    def generate(self, key: jax.Array, prompt: np.ndarray,
                 max_new: Optional[int] = None,
                 fused: bool = False) -> GenerationStats:
        cfg = self.cfg
        max_new = max_new or cfg.max_new_tokens
        prompt = np.asarray(prompt, np.int32)
        buf = len(prompt) + max_new + cfg.draft_len + 2
        uid = object()   # private session, never collides with scheduler ids
        self._admit_wave([(uid, prompt)], buf)
        block = self._block_fused if fused else self._block_cached
        out = []
        blocks = 0
        accepted_total = 0
        syncs = 0
        try:
            while len(out) < max_new:
                # Same key derivation as the reference engine so both
                # engines see identical shared uniforms (exact-match
                # testable).
                key, sub = jax.random.split(key)
                o = block([sub], [uid])[0]
                out.extend(o.new_tokens)
                accepted_total += o.accepted
                syncs += o.verify_syncs
                blocks += 1
        finally:
            self.release(uid)
        return GenerationStats(output=np.asarray(out[:max_new], np.int32),
                               blocks=blocks, accepted_drafts=accepted_total,
                               host_syncs=syncs)
