"""KV-cached speculative decoding engine (production path, dense family).

The reference engine (engine.py) re-scores the full prefix each block —
simple and family-agnostic but O(T^2) per sequence.  This engine keeps
persistent KV caches for target and drafter and advances with the
multi-token ``verify_step`` (§Perf B2):

  per block:  drafter: K decode_steps x L (drafts ride the batch dim)
              target:  ONE verify_step over (pending token + L drafts)
              fused block verification on shared uniforms (Alg. 2,
              block_verify.py — same dispatcher as the reference engine)
              cache rollback = replicate a surviving draft's rows

Cache rollback correctness: row k* survived steps 1..a, so its cache
slots [pos, pos+a] hold exactly [pending, Y_1..Y_a]; replicating row k*
into all rows and rewinding pos to pos+a+1 leaves every row's cache equal
to the accepted prefix.  The bonus/residual token Y_{a+1} becomes the
next block's pending token (its KV enters the cache when scored).
Single-draft strategies always continue along row 0, so k* = 0 there.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache, prefill
from repro.models.transformer import verify_step
from repro.specdec import verify as V
from repro.specdec.block_verify import RS_STRATEGIES, run_block_verify
from repro.specdec.engine import (
    GenerationStats,
    SpecDecConfig,
    probs_from_logits,
)


def _tree_select_row(cache, k_star: int, num_rows: int):
    """Replicate batch row ``k_star`` across all rows of every cache leaf
    with a batch dimension (layer-stacked leaves: (L, B, ...))."""

    def sel(leaf):
        if leaf.ndim >= 2 and leaf.shape[1] == num_rows:
            row = leaf[:, k_star:k_star + 1]
            return jnp.broadcast_to(row, leaf.shape)
        return leaf

    return jax.tree.map(sel, cache)


class CachedSpecDecEngine:
    """Multi-draft speculative decoding with persistent KV caches.
    Dense-family target and drafter (the paper-scale pair); all six
    verification strategies route through the shared block verifier."""

    def __init__(self, target: tuple, drafter: tuple, cfg: SpecDecConfig):
        self.t_params, self.t_cfg = target
        self.d_params, self.d_cfg = drafter
        assert self.t_cfg.family == "dense" and self.d_cfg.family == "dense"
        self.cfg = cfg
        self.vocab = self.t_cfg.vocab_size
        self._d_step = jax.jit(
            lambda p, t, c: decode_step(p, self.d_cfg, t, c))
        self._t_verify = jax.jit(
            lambda p, t, c: verify_step(p, self.t_cfg, t, c))
        self._t_prefill = jax.jit(
            lambda p, b, c: prefill(p, self.t_cfg, b, c))
        self._d_prefill = jax.jit(
            lambda p, b, c: prefill(p, self.d_cfg, b, c))

    def generate(self, key: jax.Array, prompt: np.ndarray,
                 max_new: Optional[int] = None) -> GenerationStats:
        cfg = self.cfg
        K, Lr = cfg.num_drafts, cfg.draft_len
        N = self.vocab
        max_new = max_new or cfg.max_new_tokens
        prompt = np.asarray(prompt, np.int32)
        buf = len(prompt) + max_new + Lr + 2
        need_probs = cfg.strategy in RS_STRATEGIES

        # Prefill both models with the prompt minus its last token (which
        # becomes the first pending token), replicated across K rows.
        toks = jnp.broadcast_to(jnp.asarray(prompt[None, :-1]),
                                (K, len(prompt) - 1))
        t_cache = init_cache(self.t_cfg, K, buf)
        d_cache = init_cache(self.d_cfg, K, buf)
        _, t_cache = self._t_prefill(self.t_params, {"tokens": toks}, t_cache)
        _, d_cache = self._d_prefill(self.d_params, {"tokens": toks}, d_cache)

        out = []
        pending = int(prompt[-1])
        blocks = 0
        accepted_total = 0
        syncs = 0
        while len(out) < max_new:
            # Same key derivation as the reference engine so both engines
            # see identical shared uniforms (exact-match testable).
            key, sub = jax.random.split(key)
            k_unif, k_strat = jax.random.split(sub)
            log_u = jnp.log(jax.random.uniform(
                k_unif, (Lr + 1, K, N),
                minval=np.finfo(np.float32).tiny, maxval=1.0))
            strat_keys = jax.random.split(k_strat, Lr + 1)

            # --- drafts: L decode steps, K rows advance independently ---
            d_tokens = np.zeros((K, Lr), np.int32)
            prob_steps = []
            d_cache_blk = d_cache
            cur = jnp.full((K, 1), pending, jnp.int32)
            for j in range(Lr):
                logits, d_cache_blk = self._d_step(self.d_params, cur,
                                                   d_cache_blk)
                p_all = probs_from_logits(logits, cfg.temps[0], cfg.top_k, N)
                tok = V.draft_token_from_uniforms(log_u[j], p_all)
                d_tokens[:, j] = np.asarray(tok)
                cur = tok[:, None]
                if need_probs:
                    prob_steps.append(p_all)
            d_probs = jnp.stack(prob_steps, axis=1) if need_probs else None

            # --- target: one verify chunk over [pending, drafts] ---
            chunk = np.concatenate(
                [np.full((K, 1), pending, np.int32), d_tokens], axis=1)
            t_logits, t_cache_blk = self._t_verify(
                self.t_params, jnp.asarray(chunk), t_cache)
            q_all = probs_from_logits(t_logits, cfg.target_temp, cfg.top_k, N)

            # --- fused block verification (Algorithm 2) ---
            hb = run_block_verify(
                log_u, d_tokens, d_probs, q_all, strat_keys,
                strategy=cfg.strategy, backend=cfg.verifier_backend,
                interpret=cfg.pallas_interpret)
            new_tokens = hb.new_tokens
            a = hb.num_accepted
            syncs += hb.host_syncs

            # --- cache rollback ---
            if a > 0:
                k_star = int(np.argmax(hb.active))
            else:
                k_star = 0  # any row: slot[pos] (pending) is identical
            base_pos = int(t_cache["pos"])
            t_cache = _tree_select_row(t_cache_blk, k_star, K)
            d_cache = _tree_select_row(d_cache_blk, k_star, K)
            t_cache = {**t_cache, "pos": jnp.asarray(base_pos + 1 + a,
                                                     jnp.int32)}
            d_cache = {**d_cache, "pos": jnp.asarray(base_pos + 1 + a,
                                                     jnp.int32)}
            # Drafter consumed [pending, d_1..d_{L-1}]: valid through
            # base_pos + a as long as a <= L-1; when a == L the drafter
            # cache is one token short — feed Y_L before the next block.
            if a == Lr:
                extra = jnp.full((K, 1), new_tokens[Lr - 1], jnp.int32)
                d_cache = {**d_cache, "pos": jnp.asarray(base_pos + Lr,
                                                         jnp.int32)}
                _, d_cache = self._d_step(self.d_params, extra, d_cache)

            out.extend(new_tokens)
            accepted_total += a
            pending = new_tokens[-1]
            blocks += 1
        return GenerationStats(output=np.asarray(out[:max_new], np.int32),
                               blocks=blocks, accepted_drafts=accepted_total,
                               host_syncs=syncs)
