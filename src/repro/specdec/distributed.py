"""Distributed GLS verification over a vocab-sharded mesh axis.

On a tensor-parallel serving mesh the target logits arrive vocab-sharded
(the LM head is sharded over "model").  A naive verifier would
all-gather the (K, N) probability tensor (O(N) ICI bytes per step); the
race structure makes that unnecessary: each shard races its local vocab
slice and the winner is combined with ONE all-reduce-min over a packed
(min, argmin) pair — O(K) bytes, independent of vocab size
(DESIGN.md §3, TPU adaptation of the paper's verification).

Implemented with ``shard_map`` + ``jax.lax`` collectives.  Works for any
axis size (including 1, so the CPU test path exercises the same code).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6 top-level API
    _shard_map = jax.shard_map
except AttributeError:  # older JAX: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

_TINY = 1e-30


def _local_race(log_u, probs, active):
    """Race a local vocab shard.  log_u/probs: (K, N_loc); active: (K,).
    Returns (K-draft local minima/argmins, target local min/argmin)."""
    log_s = jnp.log(-log_u)
    score = log_s - jnp.log(jnp.maximum(probs, _TINY))
    score = jnp.where(probs > 0, score, jnp.inf)
    draft_min = jnp.min(score, axis=-1)
    draft_arg = jnp.argmin(score, axis=-1).astype(jnp.int32)
    t_score = jnp.where(active[:, None], score, jnp.inf)
    col = jnp.min(t_score, axis=0)
    t_min = jnp.min(col)
    t_arg = jnp.argmin(col).astype(jnp.int32)
    return draft_min, draft_arg, t_min, t_arg


def make_sharded_gls_verify(mesh, vocab_axis: str = "model"):
    """Returns verify(log_u, draft_probs_UNUSED, target_probs, active)
    operating on vocab-sharded (K, N) inputs; outputs are replicated.

    The K draft races and the target race share one collective: the
    (min, global-argmin) pairs are reduced with psum-of-masked-argmin
    after a pmin — two scalar-sized collectives total, O(K) bytes.
    """
    axis_size = int(mesh.shape[vocab_axis])

    def kernel(log_u, target_probs, active):
        # Shapes inside shard_map: (K, N/axis) slices.
        k, n_loc = log_u.shape
        dmin, darg, tmin, targ = _local_race(log_u, target_probs, active)
        shard = jax.lax.axis_index(vocab_axis)
        offset = shard * n_loc
        # Global argmin via min-reduce then masked index reduce.
        dmin_g = jax.lax.pmin(dmin, vocab_axis)                # (K,)
        darg_global = jnp.where(dmin <= dmin_g, offset + darg,
                                jnp.int32(2**30))
        darg_g = jax.lax.pmin(darg_global, vocab_axis)         # ties -> low idx
        tmin_g = jax.lax.pmin(tmin, vocab_axis)
        targ_global = jnp.where(tmin <= tmin_g, offset + targ, jnp.int32(2**30))
        targ_g = jax.lax.pmin(targ_global, vocab_axis)
        return darg_g, targ_g

    spec_in = P(None, vocab_axis)
    fn = _shard_map(
        kernel, mesh=mesh,
        in_specs=(spec_in, spec_in, P(None)),
        out_specs=(P(None), P()))

    def verify(log_u, target_probs, active):
        """log_u/target_probs: (K, N) sharded on the vocab axis.
        Returns (token (scalar i32), accepted given draft_tokens must be
        checked by the caller, x (K,) draft race winners)."""
        x, y = fn(log_u, target_probs, active)
        return x, y

    return verify
