"""Adam(W) with decoupled weight decay, global-norm clipping and LR
schedules.  States are pytrees mirroring the params, so they shard with
the same PartitionSpecs (FSDP-friendly)."""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    mu: dict       # first moment (f32)
    nu: dict       # second moment (f32)


def adam_init(params) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros,
                     nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adam_update(
    params,
    grads,
    state: AdamState,
    lr,
    *,
    b1: float = 0.9,
    b2: float = 0.99,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    max_grad_norm: Optional[float] = 1.0,
):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    if max_grad_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        if weight_decay:
            update = update + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda x: x[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda x: x[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda x: x[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamState(step, new_mu, new_nu), {"grad_norm": gnorm}


def cosine_schedule(base_lr: float, total_steps: int, min_frac: float = 0.1):
    def lr(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return base_lr * (min_frac + (1 - min_frac) * cos)
    return lr


def warmup_cosine_schedule(base_lr: float, warmup: int, total_steps: int,
                           min_frac: float = 0.1):
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), min_frac)
    def lr(step):
        w = jnp.minimum(step / max(warmup, 1), 1.0)
        return jnp.where(step < warmup, base_lr * w, cos(step - warmup))
    return lr
