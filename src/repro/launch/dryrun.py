import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
combination on the production meshes, proving the distribution config is
coherent, and record memory/cost/collective analyses for the roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
      --shape decode_32k [--multi-pod] [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all      # everything

The XLA_FLAGS line above MUST run before any jax import (device count is
locked at first init); that is why it is the first statement of the file.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, all_configs, get_config, input_specs, supports_shape
from repro.launch.hlo_analysis import collective_stats, roofline_terms
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.models.config import ModelConfig
from repro.optim import AdamState
from repro.sharding import batch_shardings, cache_shardings, params_shardings
from repro.sharding.context import activation_sharding
from repro.sharding.rules import dp_axes


def _params_specs(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: registry.init_params(jax.random.PRNGKey(0), cfg))


def _cache_specs(cfg: ModelConfig, shape):
    return jax.eval_shape(
        lambda: registry.init_cache(cfg, shape.global_batch, shape.seq_len))


def _opt_specs(params_shape):
    zeros = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params_shape)
    return AdamState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=zeros,
                     nu=jax.tree.map(lambda x: x, zeros))


def build_lowerable(cfg: ModelConfig, shape_name: str, mesh):
    """Returns (fn, arg_shapes, in_shardings) for one (arch, shape)."""
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    params_shape = _params_specs(cfg)
    train = shape.kind == "train"
    p_shard = params_shardings(params_shape, cfg, mesh, train=train)
    b_shard = batch_shardings(specs, mesh)

    if shape.kind == "train":
        from repro.train.loop import TrainConfig, lm_loss
        from repro.optim import adam_update
        tcfg = TrainConfig()
        opt_shape = _opt_specs(params_shape)
        o_shard = AdamState(
            step=jax.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            mu=params_shardings(opt_shape.mu, cfg, mesh, train=True),
            nu=params_shardings(opt_shape.nu, cfg, mesh, train=True),
        )

        def train_step(params, opt, batch):
            def loss_fn(p):
                return lm_loss(p, cfg, batch, z_loss=tcfg.z_loss)
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            params, opt, _ = adam_update(params, grads, opt, 1e-4,
                                         weight_decay=0.01)
            return params, opt, loss

        return train_step, (params_shape, opt_shape, specs), \
            (p_shard, o_shard, b_shard)

    cache_shape = _cache_specs(cfg, shape)
    c_shard = cache_shardings(cache_shape, cfg, mesh)

    if shape.kind == "prefill":
        def prefill_step(params, batch, cache):
            return registry.prefill(params, cfg, batch, cache)
        return prefill_step, (params_shape, specs, cache_shape), \
            (p_shard, b_shard, c_shard)

    def decode_step(params, tokens, cache):
        return registry.decode_step(params, cfg, tokens, cache)
    return decode_step, (params_shape, specs["tokens"], cache_shape), \
        (p_shard, b_shard["tokens"], c_shard)


def run_one(arch: str, shape_name: str, multi_pod: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    result = {"arch": arch, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16"}
    if not supports_shape(cfg, shape_name):
        result["status"] = "skipped"
        result["reason"] = ("long_500k requires sub-quadratic decode; "
                            "see DESIGN.md")
        return result
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        fn, arg_shapes, in_shardings = build_lowerable(cfg, shape_name, mesh)
        # Pin the layer-scan carry sharding: GSPMD otherwise drops the
        # batch sharding inside the scanned blocks and replicates
        # activations (observed: full-batch f32 score tensors).  Training
        # additionally shards the sequence dim over "model"
        # (sequence-parallel) to shrink the per-layer remat stash.
        import numpy as np
        dp = dp_axes(mesh)
        dp_total = int(np.prod([mesh.shape[a] for a in dp]))
        shape = SHAPES[shape_name]
        batch_axes = dp if shape.global_batch % dp_total == 0 else (
            "data" if shape.global_batch % int(mesh.shape["data"]) == 0
            else None)
        if shape.kind == "train":
            # sequence-parallel: decoder token length must divide "model".
            from repro.configs.shapes import _token_len
            seq_axes = ("model" if _token_len(cfg, shape.seq_len)
                        % int(mesh.shape["model"]) == 0 else None)
            carry = P(batch_axes, seq_axes, None)
        else:
            carry = P(batch_axes, None, None)
        enc_seq_ok = shape.seq_len % int(mesh.shape["model"]) == 0
        hooks = {
            "layer_carry": carry,
            "enc_carry": P(batch_axes,
                           "model" if (shape.kind != "decode" and enc_seq_ok)
                           else None, None),
        }
        with mesh, activation_sharding(hooks):
            lowered = jax.jit(fn, in_shardings=in_shardings).lower(*arg_shapes)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        coll = collective_stats(hlo)
        hlo_flops = float(cost.get("flops", 0.0))
        bytes_accessed = float(cost.get("bytes accessed", 0.0))
        # Compute term from the ANALYTIC model FLOPs: XLA counts scan
        # bodies once, under-reporting scanned models by ~num_layers.
        from repro.launch.analytic import model_flops
        chips = 512 if multi_pod else 256
        mflops = model_flops(cfg, shape)
        flops_per_device = mflops / chips
        terms = roofline_terms(flops_per_device, bytes_accessed,
                               coll["total_bytes"])
        result.update({
            "status": "ok",
            "compile_s": round(time.time() - t0, 1),
            "per_device": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "flops": flops_per_device,
                "hlo_flops_scanbody": hlo_flops,
                "model_flops_global": mflops,
                "bytes_accessed": bytes_accessed,
                "collective_bytes": coll["total_bytes"],
            },
            "collectives": {k: v for k, v in coll.items() if k != "counts"},
            "collective_counts": coll["counts"],
            "roofline": terms,
        })
    except Exception as e:  # record failures — they are bugs to fix
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    runs = []
    if args.all:
        for arch in all_configs():
            for shape_name in SHAPES:
                for mp in (False, True):
                    runs.append((arch, shape_name, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        runs.append((args.arch, args.shape, args.multi_pod))

    results = []
    for arch, shape_name, mp in runs:
        r = run_one(arch, shape_name, multi_pod=mp)
        results.append(r)
        status = r["status"]
        extra = ""
        if status == "ok":
            rl = r["roofline"]
            extra = (f"compile={r['compile_s']}s "
                     f"compute={rl['compute_s']:.2e}s "
                     f"memory={rl['memory_s']:.2e}s "
                     f"coll={rl['collective_s']:.2e}s "
                     f"bound={rl['bottleneck']}")
        elif status == "error":
            extra = r["error"][:160]
        print(f"[{status:7s}] {arch:22s} {shape_name:12s} "
              f"{r['mesh']:7s} {extra}", flush=True)
        if status == "ok":
            mem = r["per_device"]
            print(f"          args={mem['argument_bytes']/1e9:.2f}GB "
                  f"temp={mem['temp_bytes']/1e9:.2f}GB "
                  f"flops={mem['flops']:.3e} "
                  f"coll={mem['collective_bytes']/1e9:.3f}GB", flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    bad = [r for r in results if r["status"] == "error"]
    if bad:
        raise SystemExit(f"{len(bad)} dry-run failures")


if __name__ == "__main__":
    main()
