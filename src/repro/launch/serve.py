"""Serving launcher: GLS multi-draft speculative decoding over a
target/drafter pair, with batched request handling.

  python -m repro.launch.serve --steps 120 --requests 4 \
      --strategy gls --drafts 8

Loads checkpoints if given, otherwise trains a small pair on the
synthetic corpus first (CPU-scale demonstration of the full path)."""

from __future__ import annotations

import argparse

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="gls",
                    choices=("gls", "gls_strong", "specinfer", "spectr",
                             "single", "daliri"))
    ap.add_argument("--drafts", type=int, default=8)
    ap.add_argument("--draft-len", type=int, default=4)
    ap.add_argument("--steps", type=int, default=120,
                    help="training steps when no checkpoint given")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--backend", default="xla",
                    choices=("legacy", "xla", "pallas"),
                    help="block-verification backend (pallas routes the "
                         "K-way race through the gls_race kernel)")
    args = ap.parse_args()

    import sys, os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                    "..", "..", ".."))
    from benchmarks.lm_pair import bench_prompts, get_pair
    from repro.specdec import SpecDecConfig, SpecDecEngine

    target, drafter = get_pair(steps=args.steps, log=print)
    k = 1 if args.strategy in ("single", "daliri") else args.drafts
    eng = SpecDecEngine(
        target, [drafter],
        SpecDecConfig(num_drafts=k, draft_len=args.draft_len,
                      strategy=args.strategy, top_k=50,
                      max_new_tokens=args.max_new,
                      verifier_backend=args.backend))
    prompts = bench_prompts(args.requests)
    results = eng.serve(jax.random.PRNGKey(0), prompts)
    be = float(np.mean([r.block_efficiency for r in results]))
    syncs = sum(r.host_syncs for r in results)
    print(f"strategy={args.strategy} K={k} L={args.draft_len} "
          f"backend={args.backend} BE={be:.2f} "
          f"verify-syncs={syncs} over {len(prompts)} requests")


if __name__ == "__main__":
    main()
