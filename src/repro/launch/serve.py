"""Serving launcher: GLS multi-draft speculative decoding over a
target/drafter pair, driven by the batched request scheduler.

  python -m repro.launch.serve --steps 120 --requests 4 \
      --strategy gls --drafts 8 --cache-mode kv

``--cache-mode reprefill`` drives the reference engine (full-prefix
re-score per block; add ``--batched`` to stack live requests into one
target forward per round); ``--cache-mode kv`` serves from persistent
KV caches in a multi-request slot pool (DESIGN.md §7) — same tokens,
no re-prefill; ``--cache-mode kv_fused`` additionally runs each whole
round as ONE jitted device program (DESIGN.md §8) — same tokens again,
zero draft syncs, one host sync per round.  ``--paged`` swaps the slot
arena for the paged KV arena and ``--policy v2 --preempt-tokens N``
turns on eviction/re-admission + rotation preemption (DESIGN.md §12)
— same tokens in every combination.

Loads checkpoints if given, otherwise trains a small pair on the
synthetic corpus first (CPU-scale demonstration of the full path)."""

from __future__ import annotations

import argparse

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="gls",
                    choices=("gls", "gls_strong", "specinfer", "spectr",
                             "single", "daliri"))
    ap.add_argument("--drafts", type=int, default=8)
    ap.add_argument("--draft-len", type=int, default=4)
    ap.add_argument("--steps", type=int, default=120,
                    help="training steps when no checkpoint given")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--backend", default="xla",
                    choices=("legacy", "xla", "pallas"),
                    help="block-verification backend (pallas routes the "
                         "K-way race through the gls_race kernel)")
    ap.add_argument("--cache-mode", default="reprefill",
                    choices=("reprefill", "kv", "kv_fused"),
                    help="reprefill: reference engine, full-prefix "
                         "re-score; kv: persistent KV caches in a "
                         "multi-request slot pool; kv_fused: kv with "
                         "the whole round fused into one device program")
    ap.add_argument("--batched", action="store_true",
                    help="stack live requests into one target forward "
                         "per round (reprefill mode; kv always batches)")
    ap.add_argument("--admission", default="bucketed",
                    choices=("bucketed", "per_request"),
                    help="bucketed: batched admission — prompts prefill "
                         "straight into pool slots, one stacked dispatch "
                         "per length bucket per model, overlapped with "
                         "the running round under kv_fused (DESIGN.md "
                         "§9); per_request: the 2-dispatches-per-request "
                         "reference path")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV arena (DESIGN.md §12): fixed-size "
                         "time pages behind a device page table — the "
                         "queue can oversubscribe physical capacity "
                         "and preemption parks pages instead of "
                         "discarding KV (kv/kv_fused only)")
    ap.add_argument("--policy", default="fifo", choices=("fifo", "v2"),
                    help="v2: priority-ordered admission with "
                         "eviction/re-admission and preemption "
                         "(kv/kv_fused only)")
    ap.add_argument("--preempt-tokens", type=int, default=None,
                    help="per-request rotation quantum: suspend a "
                         "request after this many new tokens when "
                         "others are waiting (policy v2)")
    ap.add_argument("--prefill-kernel", action="store_true",
                    help="route admission prefill chunks through the "
                         "flash-attention Pallas kernel (numerically "
                         "equivalent, not bit-equal)")
    ap.add_argument("--fault-rate", type=float, default=None,
                    help="chaos mode (DESIGN.md §13): inject every "
                         "fault class at this per-request-per-round "
                         "rate; survivors replay bit-identically")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="deterministic injection seed for --fault-rate")
    ap.add_argument("--retry-budget", type=int, default=None,
                    help="per-request fault retries before quarantine "
                         "(default 2; passing it arms the guard layer)")
    ap.add_argument("--round-timeout-ms", type=float, default=None,
                    help="per-round wall-clock watchdog budget")
    ap.add_argument("--degrade-after", type=int, default=None,
                    help="consecutive faults before stepping down the "
                         "degradation ladder (pallas->xla, quant->f32, "
                         "kv_fused->kv->reprefill)")
    args = ap.parse_args()
    if args.cache_mode == "kv_fused" and args.backend == "legacy":
        ap.error("--cache-mode kv_fused needs a device verifier backend "
                 "(xla or pallas)")
    if (args.paged or args.policy == "v2") and \
            args.cache_mode not in ("kv", "kv_fused"):
        ap.error("--paged / --policy v2 need --cache-mode kv or kv_fused")

    import sys, os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                    "..", "..", ".."))
    from benchmarks.lm_pair import bench_prompts, get_pair
    from repro.serving import FaultPlan
    from repro.specdec import (
        CachedSpecDecEngine,
        SpecDecConfig,
        SpecDecEngine,
        SpecDecServer,
    )

    target, drafter = get_pair(steps=args.steps, log=print)
    k = 1 if args.strategy in ("single", "daliri") else args.drafts
    cfg = SpecDecConfig(num_drafts=k, draft_len=args.draft_len,
                        strategy=args.strategy, top_k=50,
                        max_new_tokens=args.max_new,
                        verifier_backend=args.backend,
                        prefill_kernel=args.prefill_kernel,
                        paged=args.paged)
    if args.cache_mode in ("kv", "kv_fused"):
        eng = CachedSpecDecEngine(target, drafter, cfg,
                                  pool_slots=args.max_batch)
    else:
        eng = SpecDecEngine(target, [drafter], cfg)
    plan = None
    if args.fault_rate is not None:
        slow_ms = (args.round_timeout_ms * 2.0
                   if args.round_timeout_ms else 100.0)
        plan = FaultPlan.uniform(args.fault_rate, seed=args.fault_seed,
                                 slow_ms=slow_ms)
    server = SpecDecServer(eng, max_batch=args.max_batch,
                           batched=args.batched,
                           cache_mode=args.cache_mode,
                           admission=args.admission,
                           policy=args.policy,
                           preempt_tokens=args.preempt_tokens,
                           fault_plan=plan,
                           retry_budget=args.retry_budget,
                           round_timeout_ms=args.round_timeout_ms,
                           degrade_after=args.degrade_after)
    for p in bench_prompts(args.requests):
        server.submit(p, max_new=args.max_new)
    done = server.run(jax.random.PRNGKey(0))
    m = server.metrics
    be = float(np.mean([r.block_efficiency for r in done]))
    ttft = float(np.mean([r.ttft_ms for r in done]))
    pd = getattr(eng, "num_prefill_dispatches", 0)
    print(f"strategy={args.strategy} K={k} L={args.draft_len} "
          f"backend={args.backend} cache_mode={args.cache_mode} "
          f"admission={args.admission} "
          f"BE={be:.2f} tok/s={m.tokens_per_s:.1f} "
          f"mean-ttft={ttft:.1f}ms prefill-dispatches={pd} "
          f"rounds={m.rounds} target-forwards={m.target_forwards} "
          f"verify-syncs={m.host_syncs} draft-syncs={m.draft_syncs} "
          f"evictions={m.evictions} preemptions={m.preemptions} "
          f"over {len(done)} requests")
    if server.guarded:
        print(f"faults={dict(m.faults)} retries={m.retries} "
              f"quarantined={m.quarantined} "
              f"watchdog-trips={m.watchdog_trips} "
              f"watchdog-accepts={m.watchdog_accepts} "
              f"degradations={[d['step'] for d in m.degradations]} "
              f"failed={len(server.failed)}")


if __name__ == "__main__":
    main()
