"""Analytic FLOP model: MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference),
with N the parameter count (active params for MoE).  Used for the
roofline compute term because XLA's cost_analysis counts lax.scan bodies
once, under-reporting scanned models by ~num_layers (see
EXPERIMENTS.md §Roofline notes)."""

from __future__ import annotations

import jax

from repro.configs.shapes import SHAPES, InputShape
from repro.models import registry
from repro.models.config import ModelConfig


def param_count(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(
        lambda: registry.init_params(jax.random.PRNGKey(0), cfg))
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(shapes))


def active_param_count(cfg: ModelConfig) -> float:
    n = param_count(cfg)
    if cfg.num_experts:
        expert = cfg.num_layers * cfg.num_experts * 3 * cfg.d_model * cfg.d_ff
        n = n - expert + expert * cfg.experts_per_token / cfg.num_experts
    return float(n)


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    n = active_param_count(cfg)
    if shape.kind == "train":
        tl = (min(shape.seq_len, cfg.max_decoder_len)
              if cfg.family == "encdec" else shape.seq_len)
        return 6.0 * n * shape.global_batch * tl
    if shape.kind == "prefill":
        tl = (min(shape.seq_len, cfg.max_decoder_len)
              if cfg.family == "encdec" else shape.seq_len)
        return 2.0 * n * shape.global_batch * tl
    return 2.0 * n * shape.global_batch  # decode: one token per row
