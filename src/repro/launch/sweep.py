import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run sweep worker: runs a subset of (arch x shape x mesh) combos and
writes one JSON per combo to --outdir.  Split across processes by
--worker/--num-workers."""

import argparse
import json

from repro.configs import ARCH_NAMES, SHAPES
from repro.launch.dryrun import run_one


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", required=True)
    ap.add_argument("--worker", type=int, default=0)
    ap.add_argument("--num-workers", type=int, default=1)
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    combos = []
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            for mp in (False, True):
                combos.append((arch, shape, mp))
    for i, (arch, shape, mp) in enumerate(combos):
        if i % args.num_workers != args.worker:
            continue
        tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
        path = os.path.join(args.outdir, tag + ".json")
        if os.path.exists(path):
            print(f"skip cached {tag}", flush=True)
            continue
        r = run_one(arch, shape, multi_pod=mp)
        with open(path, "w") as f:
            json.dump(r, f, indent=1)
        rl = r.get("roofline", {})
        print(f"[{r['status']:7s}] {tag} "
              f"{rl.get('bottleneck', r.get('error', '')[:80])}", flush=True)


if __name__ == "__main__":
    main()
