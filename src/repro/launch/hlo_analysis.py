"""Post-optimization HLO analysis: loop-aware collective traffic and
roofline terms.

XLA's ``cost_analysis()`` counts each while-loop (lax.scan) body ONCE, not
times its trip count — a 126-layer scanned model under-reports per-layer
work by ~126x.  ``collective_stats`` therefore walks the HLO text, parses
every while's trip count from the constant in its condition computation,
propagates multipliers through nested loops from ENTRY, and weights each
collective by its effective execution count.

Per-op ring-algorithm traffic models (s = replica-group size):
  all-gather          out_bytes * (s-1)/s
  all-reduce          2 * bytes * (s-1)/s
  reduce-scatter      result_bytes * (s-1)
  all-to-all          bytes * (s-1)/s
  collective-permute  bytes

Result shapes in post-opt SPMD HLO are per-device, so all outputs here are
per-device — matching the per-device roofline convention.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_COMP_RE = re.compile(r"^(ENTRY\s+)?(%?[\w.\-]+)\s*\([^)]*\)\s*->.*\{\s*$")
_SHAPE_RE = re.compile(r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_WHILE_RE = re.compile(r"while\(.*?\),\s*condition=(%?[\w.\-]+),\s*"
                       r"body=(%?[\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype, 4)
    total = 1
    for d in dims.split(",") if dims else []:
        total *= int(d)
    return total * nbytes


def _parse_computations(hlo_text: str):
    """Split HLO text into {computation_name: [lines]} (entry included)."""
    comps: Dict[str, list] = {}
    current = None
    entry = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m:
            current = m.group(2)
            if m.group(1):
                entry = current
            comps[current] = []
            continue
        if current is not None:
            comps[current].append(line)
            if line.strip() == "}":
                current = None
    return comps, entry


def _loop_multipliers(comps: Dict[str, list], entry: str) -> Dict[str, float]:
    """Effective execution count per computation, propagated from ENTRY
    through (possibly nested) while loops."""
    # For each computation: which (cond, body) loops does it contain?
    contains = defaultdict(list)
    for name, lines in comps.items():
        for line in lines:
            w = _WHILE_RE.search(line)
            if w:
                contains[name].append((w.group(1), w.group(2)))

    def trip_of(cond_name: str) -> float:
        best = 1
        for line in comps.get(cond_name, ()):
            for c in _CONST_RE.findall(line):
                best = max(best, int(c))
        return float(best)

    mult = defaultdict(float)
    mult[entry] = 1.0
    frontier = [entry]
    seen = set()
    while frontier:
        name = frontier.pop()
        if name in seen:
            continue
        seen.add(name)
        for cond, body in contains.get(name, ()):
            m = mult[name] * trip_of(cond)
            if m > mult[body]:
                mult[body] = m
                seen.discard(body)
            frontier.append(body)
    return mult


def collective_stats(hlo_text: str) -> Dict[str, float]:
    """Per-device collective traffic (bytes) by op kind + total, weighted
    by loop execution counts."""
    comps, entry = _parse_computations(hlo_text)
    if entry is None:  # fallback: flat scan, no loop weighting
        comps = {"<all>": hlo_text.splitlines()}
        mult = {"<all>": 1.0}
    else:
        mult = _loop_multipliers(comps, entry)

    out = {op: 0.0 for op in _COLLECTIVES}
    counts = {op: 0 for op in _COLLECTIVES}
    for name, lines in comps.items():
        weight = mult.get(name, 1.0)
        # Computations never reached from ENTRY via whiles (fusions,
        # reducers) hold no collectives in practice; weight 1 is safe.
        for line in lines:
            stripped = line.strip()
            op = next((o for o in _COLLECTIVES
                       if f" {o}(" in stripped or f" {o}-start(" in stripped),
                      None)
            if op is None:
                continue
            m = _SHAPE_RE.search(stripped)
            if not m:
                continue
            bytes_ = _shape_bytes(m.group(1), m.group(2))
            g = _GROUPS_RE.search(stripped)
            s = int(g.group(2)) if g else 2
            frac = (s - 1) / s if s > 1 else 1.0
            if op == "all-gather":
                traffic = bytes_ * frac
            elif op == "all-reduce":
                traffic = 2.0 * bytes_ * frac
            elif op == "reduce-scatter":
                traffic = bytes_ * max(s - 1, 1)
            elif op == "all-to-all":
                traffic = bytes_ * frac
            else:
                traffic = float(bytes_)
            out[op] += traffic * weight
            counts[op] += 1
    out["total_bytes"] = sum(out[o] for o in _COLLECTIVES)
    out["counts"] = counts
    return out


# TPU v5e hardware model (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   collective_bytes_per_device: float) -> Dict[str, float]:
    compute_s = flops_per_device / PEAK_FLOPS_BF16
    memory_s = bytes_per_device / HBM_BW
    collective_s = collective_bytes_per_device / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    terms["bottleneck"] = max(("compute_s", "memory_s", "collective_s"),
                              key=lambda k: terms[k])
    return terms
