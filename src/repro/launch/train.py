"""Distributed training launcher.

On real hardware (TPU pod), run under your cluster runtime:

  python -m repro.launch.train --arch granite-8b --steps 1000 \
      [--multi-pod]

On this CPU container, use --host-mesh --reduced for a runnable
single-device demonstration of the same code path (identical pjit
program, 1-device mesh)."""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--host-mesh", action="store_true",
                    help="1-device mesh for CPU demonstration")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data import lm_dataset
    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.models import init_params, param_count
    from repro.optim import adam_init
    from repro.sharding import batch_shardings, params_shardings
    from repro.train.loop import TrainConfig, make_train_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit("text-LM launcher: decoder-only archs")
    mesh = (make_host_mesh() if args.host_mesh
            else make_production_mesh(multi_pod=args.multi_pod))

    with mesh:
        params = init_params(jax.random.PRNGKey(0), cfg)
        print(f"{cfg.name}: {param_count(params):,} params, mesh={dict(mesh.shape)}")
        p_shard = params_shardings(
            jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                         params), cfg, mesh, train=True)
        params = jax.device_put(params, p_shard)
        opt = adam_init(params)
        tcfg = TrainConfig(total_steps=args.steps,
                           log_every=max(args.steps // 10, 1))
        step_fn = make_train_step(cfg, tcfg)
        ds = iter(lm_dataset(args.batch, args.seq, cfg.vocab_size))
        for step in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(ds).items()}
            params, opt, metrics = step_fn(params, opt, batch)
            if step % tcfg.log_every == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss {float(metrics['loss']):.4f}")
    if args.checkpoint:
        from repro.train import save_checkpoint
        save_checkpoint(args.checkpoint, {"params": params})


if __name__ == "__main__":
    main()
