"""Production mesh builders.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (per the dry-run contract)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; (2,16,16) = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
