"""Production mesh builders.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (per the dry-run contract).

``compat_make_mesh`` papers over the jax.sharding.AxisType API (added in
newer JAX): on versions without it, ``axis_types`` is simply omitted —
meshes default to Auto axes there, so semantics are unchanged.
"""

from __future__ import annotations

import jax


def compat_make_mesh(shape: tuple, axes: tuple):
    """jax.make_mesh with Auto axis types where the API supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; (2,16,16) = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (tests/examples)."""
    return compat_make_mesh((1, 1), ("data", "model"))
