"""Procedurally generated MNIST-like digits (28x28, values in [0,1]).

MNIST itself is not available offline; we synthesize structurally similar
data — glyph bitmaps with random shift, thickness and pixel noise — so
the β-VAE compression pipeline (paper Sec. 5, Fig. 3/4) runs end-to-end.
DESIGN.md §6 records this substitution.
"""

from __future__ import annotations

import numpy as np

# 7x5 bitmap font for digits 0-9.
_GLYPHS = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00110", "01000", "10000", "11111"],
    3: ["11110", "00001", "00001", "01110", "00001", "00001", "11110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}


def _glyph_array(d: int) -> np.ndarray:
    return np.array([[int(c) for c in row] for row in _GLYPHS[d]], np.float32)


def _render(digit: int, rng: np.random.Generator) -> np.ndarray:
    img = np.zeros((28, 28), np.float32)
    g = _glyph_array(digit)
    scale = rng.integers(2, 4)  # 2x or 3x upscaling
    up = np.kron(g, np.ones((scale, scale), np.float32))
    h, w = up.shape
    oy = rng.integers(2, 28 - h - 1)
    ox = rng.integers(2, 28 - w - 1)
    img[oy:oy + h, ox:ox + w] = up
    # Slight blur via box filter to soften edges.
    pad = np.pad(img, 1)
    img = (pad[:-2, 1:-1] + pad[2:, 1:-1] + pad[1:-1, :-2] + pad[1:-1, 2:]
           + 4 * img) / 8.0
    img += rng.normal(0, 0.05, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def digits_dataset(n: int, seed: int = 0):
    """Returns (images (n,28,28), labels (n,))."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, n)
    images = np.stack([_render(int(d), rng) for d in labels])
    return images.astype(np.float32), labels.astype(np.int32)


def wz_split(images: np.ndarray, rng: np.random.Generator):
    """Paper Sec. 5.2 split: the RIGHT half (28x14) is the source; the side
    information is a random 7x7 crop from the LEFT half."""
    right = images[:, :, 14:]
    n = images.shape[0]
    oy = rng.integers(0, 21, n)
    ox = rng.integers(0, 7, n)
    crops = np.stack([images[i, oy[i]:oy[i] + 7, ox[i]:ox[i] + 7]
                      for i in range(n)])
    return right, crops
