"""Offline data pipelines: synthetic text corpus + MNIST-like digits."""

from repro.data.mnist import digits_dataset, wz_split
from repro.data.text import (
    BOS,
    EOS,
    PAD,
    VOCAB_SIZE,
    PackedDataset,
    decode,
    encode,
    lm_dataset,
    synthetic_corpus,
)

__all__ = [
    "BOS", "EOS", "PAD", "VOCAB_SIZE", "PackedDataset", "decode",
    "digits_dataset", "encode", "lm_dataset", "synthetic_corpus", "wz_split",
]
