"""Offline text data pipeline: a deterministic synthetic corpus (no
downloads in this container), a byte-level tokenizer, and a packed
batching iterator.

The synthetic corpus is structured English-like text with heavy n-gram
regularities so that (a) a ~100M target model trained for a few hundred
steps becomes meaningfully predictable and (b) a small drafter aligns
with it — the regime where speculative decoding pays off, mirroring the
paper's GSM8K/HumanEval-style evaluation at laptop scale.
"""

from __future__ import annotations

import numpy as np

VOCAB_SIZE = 256 + 3  # bytes + BOS/EOS/PAD
BOS, EOS, PAD = 256, 257, 258

_SUBJECTS = ["the engineer", "a student", "the model", "our system",
             "the decoder", "the encoder", "a reviewer", "the compiler"]
_VERBS = ["computes", "samples", "accepts", "rejects", "verifies",
          "couples", "compresses", "matches", "proposes", "decodes"]
_OBJECTS = ["the token", "a draft", "the sequence", "a distribution",
            "the message", "the index", "the residual", "an estimate"]
_MODS = ["quickly", "exactly", "with high probability", "in parallel",
         "without communication", "at a lower rate", "per step",
         "using shared randomness"]
_MATH = ["1 + 2 = 3", "2 * 3 = 6", "7 - 4 = 3", "9 / 3 = 3", "5 + 5 = 10",
         "8 - 6 = 2", "4 * 4 = 16", "6 + 7 = 13"]


def synthetic_corpus(num_sentences: int = 20_000, seed: int = 0) -> str:
    rng = np.random.default_rng(seed)
    parts = []
    for _ in range(num_sentences):
        if rng.random() < 0.2:
            parts.append(f"we check that {rng.choice(_MATH)} .")
        else:
            parts.append(
                f"{rng.choice(_SUBJECTS)} {rng.choice(_VERBS)} "
                f"{rng.choice(_OBJECTS)} {rng.choice(_MODS)} .")
    return " ".join(parts)


def encode(text: str) -> np.ndarray:
    return np.frombuffer(text.encode("utf-8"), np.uint8).astype(np.int32)


def decode(tokens) -> str:
    toks = [t for t in np.asarray(tokens).tolist() if t < 256]
    return bytes(toks).decode("utf-8", errors="replace")


class PackedDataset:
    """Pack a token stream into (batch, seq) blocks; targets are inputs
    shifted by one (standard LM objective)."""

    def __init__(self, tokens: np.ndarray, batch: int, seq: int,
                 seed: int = 0):
        self.tokens = tokens
        self.batch = batch
        self.seq = seq
        self.rng = np.random.default_rng(seed)
        self.n_blocks = (len(tokens) - 1) // seq

    def __iter__(self):
        return self

    def __next__(self):
        starts = self.rng.integers(0, len(self.tokens) - self.seq - 1,
                                   self.batch)
        x = np.stack([self.tokens[s:s + self.seq] for s in starts])
        y = np.stack([self.tokens[s + 1:s + self.seq + 1] for s in starts])
        return {"tokens": x, "targets": y}


def lm_dataset(batch: int, seq: int, vocab_size: int, seed: int = 0,
               num_sentences: int = 20_000) -> PackedDataset:
    """Corpus tokenized and folded into ``vocab_size`` (byte ids are
    taken mod vocab when models use a smaller vocabulary)."""
    toks = encode(synthetic_corpus(num_sentences, seed))
    if vocab_size < VOCAB_SIZE:
        toks = toks % vocab_size
    return PackedDataset(toks, batch, seq, seed)
