"""msgpack-based checkpointing for param/optimizer pytrees (no orbax
offline).  Arrays are serialized as (dtype, shape, bytes); the pytree
structure is encoded as nested dicts/lists."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack(obj):
    if isinstance(obj, (jnp.ndarray, np.ndarray)) or hasattr(obj, "dtype"):
        arr = np.asarray(obj)
        return {"__nd__": True, "dtype": str(arr.dtype),
                "shape": list(arr.shape), "data": arr.tobytes()}
    if isinstance(obj, dict):
        return {k: _pack(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return {"__seq__": type(obj).__name__, "items": [_pack(v) for v in obj]}
    return obj


def _unpack(obj):
    if isinstance(obj, dict):
        if obj.get("__nd__"):
            arr = np.frombuffer(obj["data"], obj["dtype"]).reshape(obj["shape"])
            return jnp.asarray(arr)
        if "__seq__" in obj:
            items = [_unpack(v) for v in obj["items"]]
            return tuple(items) if obj["__seq__"] == "tuple" else items
        return {k: _unpack(v) for k, v in obj.items()}
    return obj


def save_checkpoint(path: str, tree) -> None:
    tmp = path + ".tmp"
    host_tree = jax.tree.map(
        lambda a: np.asarray(a) if hasattr(a, "dtype") else a, tree)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(_pack(host_tree), use_bin_type=True))
    os.replace(tmp, path)


def load_checkpoint(path: str):
    with open(path, "rb") as f:
        return _unpack(msgpack.unpackb(f.read(), raw=False,
                                       strict_map_key=False))
