"""Training loop + checkpointing."""

from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.loop import TrainConfig, lm_loss, make_train_step, train

__all__ = ["TrainConfig", "lm_loss", "load_checkpoint", "make_train_step",
           "save_checkpoint", "train"]
