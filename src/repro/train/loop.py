"""Training loop: jitted LM train step (optionally pjit-sharded via the
sharding rules in repro.sharding) + a simple host loop with logging and
checkpointing."""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import forward
from repro.models.config import ModelConfig
from repro.optim import AdamState, adam_init, adam_update, warmup_cosine_schedule


@dataclasses.dataclass
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 20
    total_steps: int = 300
    weight_decay: float = 0.01
    max_grad_norm: float = 1.0
    log_every: int = 20
    z_loss: float = 1e-4      # logit-norm regularizer (stabilizes bf16)
    moe_aux_weight: float = 0.01


# Above this many (seq x padded_vocab) logit elements per batch row, the
# cross-entropy is computed in sequence chunks with rematerialization so
# the full (B, S, V) logits tensor is never alive at once.  At 405B scale
# (S=4096, V=128k) the monolithic f32 logits would be ~2 TB/device.
CHUNKED_CE_THRESHOLD = 1 << 24
CE_CHUNK = 512


def _head_matrix(params, cfg: ModelConfig):
    if cfg.family == "encdec":          # tied head
        return params["embed"].T
    return params["lm_head"]


def _masked_ce_terms(logits, targets, vocab_size):
    """Returns (sum nll, sum logz^2, count) for one logits block."""
    logits = logits.astype(jnp.float32)
    neg = jnp.finfo(jnp.float32).min
    mask = jnp.arange(logits.shape[-1]) < vocab_size
    logits = jnp.where(mask, logits, neg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tok = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(logz - tok), jnp.sum(jnp.square(logz))


def chunked_ce(x, head, targets, vocab_size, chunk: int = CE_CHUNK):
    """Cross-entropy over sequence chunks: logits (B, C, V) materialize one
    chunk at a time and are rematerialized on the backward pass."""
    b, s, d = x.shape
    while s % chunk:
        chunk //= 2
    nc = s // chunk
    xs = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    ts = targets.reshape(b, nc, chunk).transpose(1, 0, 2)

    def body(carry, xs_c):
        xc, tc = xs_c
        nll_s, zz_s = _masked_ce_terms(xc @ head, tc, vocab_size)
        return (carry[0] + nll_s, carry[1] + zz_s), None

    (nll_sum, zz_sum), _ = jax.lax.scan(
        jax.checkpoint(body, prevent_cse=False), (0.0, 0.0), (xs, ts))
    n = b * s
    return nll_sum / n, zz_sum / n


def lm_loss(params, cfg: ModelConfig, batch: dict, *,
            z_loss: float = 1e-4, moe_aux_weight: float = 0.01,
            remat: bool = True):
    """Next-token cross-entropy with pad-vocab masking + optional MoE
    load-balance auxiliary loss.  Large (S x V) uses chunked CE."""
    aux = 0.0
    s_dec = batch["targets"].shape[1]
    use_chunked = s_dec * cfg.padded_vocab > CHUNKED_CE_THRESHOLD
    if cfg.family == "moe":
        from repro.models import moe
        out, aux = moe.forward(params, cfg, batch, remat=remat,
                               return_aux=True, return_hidden=use_chunked)
    else:
        out = forward(params, cfg, batch, remat=remat,
                      return_hidden=use_chunked)
    tgt = batch["targets"]
    if use_chunked:
        nll, zz = chunked_ce(out, _head_matrix(params, cfg), tgt,
                             cfg.vocab_size)
    else:
        nll_sum, zz_sum = _masked_ce_terms(out, tgt, cfg.vocab_size)
        n = tgt.size
        nll, zz = nll_sum / n, zz_sum / n
    loss = nll + z_loss * zz
    if cfg.family == "moe":
        loss = loss + moe_aux_weight * aux
    return loss, {"nll": nll}


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    shardings: Optional[dict] = None) -> Callable:
    """Build a jitted train step.  ``shardings`` (optional) is a dict with
    'params'/'opt'/'batch' NamedSharding pytrees for pjit execution."""
    lr_fn = warmup_cosine_schedule(tcfg.lr, tcfg.warmup, tcfg.total_steps)

    def step(params, opt: AdamState, batch):
        def loss_fn(p):
            return lm_loss(p, cfg, batch, z_loss=tcfg.z_loss,
                           moe_aux_weight=tcfg.moe_aux_weight)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        lr = lr_fn(opt.step)
        params, opt, opt_metrics = adam_update(
            params, grads, opt, lr, weight_decay=tcfg.weight_decay,
            max_grad_norm=tcfg.max_grad_norm)
        return params, opt, {"loss": loss, "lr": lr, **metrics, **opt_metrics}

    kw = {}
    if shardings is not None:
        kw = dict(
            in_shardings=(shardings["params"], shardings["opt"],
                          shardings["batch"]),
            out_shardings=(shardings["params"], shardings["opt"], None),
        )
    return jax.jit(step, donate_argnums=(0, 1), **kw)


def train(params, cfg: ModelConfig, tcfg: TrainConfig, dataset,
          checkpoint_path: Optional[str] = None, log=print):
    """Host training loop.  Returns (params, history)."""
    opt = adam_init(params)
    step_fn = make_train_step(cfg, tcfg)
    history = []
    it = iter(dataset)
    t0 = time.time()
    for step in range(tcfg.total_steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        if step % tcfg.log_every == 0 or step == tcfg.total_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["elapsed_s"] = round(time.time() - t0, 1)
            history.append(m)
            log(f"step {step:4d} loss {m['loss']:.4f} "
                f"nll {m['nll']:.4f} lr {m['lr']:.2e}")
    if checkpoint_path:
        from repro.train.checkpoint import save_checkpoint
        save_checkpoint(checkpoint_path, {"params": params})
    return params, history
