"""Layer-stacking helpers: params carry a leading layer axis and blocks are
applied with ``lax.scan`` so the HLO stays O(1) in depth (essential when
lowering 126-layer models for the 512-chip dry-run)."""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def stack_init(key: jax.Array, n: int, init_fn: Callable[[jax.Array], dict]) -> dict:
    """vmap an init function over n per-layer keys -> stacked param pytree."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def scan_blocks(
    stacked_params,
    x: jax.Array,
    fn: Callable,
    *,
    cache=None,
    remat: bool = False,
):
    """Apply ``fn(layer_params, x, layer_cache) -> (x, new_layer_cache)``
    over the stacked layer axis.

    Returns (x, new_cache) where new_cache mirrors ``cache``'s stacking.
    When ``cache`` is None, fn is called with None and must return
    (x, None).
    """
    body_fn = fn
    if remat:
        body_fn = jax.checkpoint(fn, prevent_cse=False)

    def step(carry, xs):
        params_l, cache_l = xs
        y, new_cache_l = body_fn(params_l, carry, cache_l)
        return y, new_cache_l

    if cache is None:
        n = jax.tree_util.tree_leaves(stacked_params)[0].shape[0]
        cache_xs = jnp.zeros((n, 0))  # dummy, same leading dim
        out, _ = jax.lax.scan(
            lambda c, xs: (body_fn(xs[0], c, None)[0], None),
            x, (stacked_params, cache_xs))
        return out, None

    out, new_cache = jax.lax.scan(step, x, (stacked_params, cache))
    return out, new_cache
