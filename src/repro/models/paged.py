"""Paged KV storage: page-table indirection between the slot-arena view
the models compute on and fixed-size physical pages on device.

The contiguous pool (``cache_pool.CachePool``) stores each model's KV as
one ``(layers, rows, kv_heads, buf_len, head_dim)`` arena; a longer
request forces ``ensure_buf`` to zero-pad-regrow the WHOLE arena and a
free slot still owns ``buf_len`` tokens of storage.  The paged pool
replaces the time axis with chains of fixed-size *pages*:

  physical storage  (layers, num_pages + 1, kv_heads, page_size, head_dim)
  page table        (rows, n_logical_pages) int32

Row ``b``'s logical KV positions ``[lp * page_size, (lp+1) * page_size)``
live in physical page ``table[b, lp]``.  Entry 0 is UNMAPPED; physical
page 0 is a permanent all-zero page, so a gather through an unmapped
entry reads zeros and a scatter to an unmapped entry is redirected out
of bounds and dropped (``mode="drop"``) — the zero page is never
written.  Growing ``buf_len`` is now a table-widening (append unmapped
columns), not a storage copy, and an oversubscribed scheduler can hold
more slots than physical pages as long as the *live* chains fit.

Bit-identity contract (the gate for the whole refactor): a gathered view
is sliced to exactly ``buf_len`` positions, so every model computation
runs at the same reduction shapes as the contiguous arena.  Where a
chain is mapped, view content equals arena content; where it is not,
the view reads the zero page — both are beyond the row's ``kv_len`` and
masked to exact ``-inf`` scores (probability exactly 0), so the
difference is token-invisible (the same dead-row argument DESIGN.md §7
makes for the contiguous pool).

All helpers take either a per-layer leaf ``(P+1, H, page, d)`` (used
inside ``scan_blocks`` so only ONE layer's contiguous view is ever
materialized) or, via the ``*_arena`` wrappers, a stacked
``(layers, P+1, H, page, d)`` leaf.  Quant pools (DESIGN.md §11) page
their int8 ``k``/``v`` and f32 ``k_s``/``v_s`` scale leaves through the
same functions — only the trailing dim differs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def n_logical_pages(buf_len: int, page_size: int) -> int:
    """Pages needed to cover ``buf_len`` tokens (ceil division)."""
    return -(-buf_len // page_size)


# ---------------------------------------------------------------------------
# Per-layer primitives (the scan_blocks building blocks)
# ---------------------------------------------------------------------------


def gather_layer(pages_l: jax.Array, table: jax.Array,
                 buf_len: int) -> jax.Array:
    """Materialize one layer's contiguous ``(rows, H, buf_len, d)`` view
    from ``pages_l (P+1, H, page, d)`` through ``table (rows, n_lp)``.
    Unmapped entries read physical page 0 (the zero page)."""
    rows, n_lp = table.shape
    _, h, page, d = pages_l.shape
    v = jnp.take(pages_l, table.reshape(-1), axis=0)
    v = v.reshape(rows, n_lp, h, page, d)
    v = jnp.swapaxes(v, 1, 2).reshape(rows, h, n_lp * page, d)
    return v[:, :, :buf_len]


def scatter_layer(pages_l: jax.Array, table: jax.Array,
                  view_l: jax.Array) -> jax.Array:
    """Write a contiguous ``(rows, H, T, d)`` view back through the page
    table.  ``T <= n_lp * page``; the pad tail and every position whose
    table entry is unmapped redirect out of bounds and DROP, so the zero
    page and pages owned by other rows are bit-untouched.  Mapped
    physical pages appear in exactly one table entry (allocator
    invariant), so the scatter has no write conflicts."""
    rows, n_lp = table.shape
    p1, h, page, d = pages_l.shape
    t = view_l.shape[2]
    if t < n_lp * page:
        view_l = jnp.pad(
            view_l, ((0, 0), (0, 0), (0, n_lp * page - t), (0, 0)))
    v = view_l.reshape(rows, h, n_lp, page, d)
    v = jnp.swapaxes(v, 1, 2).reshape(rows * n_lp, h, page, d)
    idx = table.reshape(-1)
    idx = jnp.where(idx > 0, idx, p1)        # unmapped -> OOB -> dropped
    return pages_l.at[idx].set(v, mode="drop")


# ---------------------------------------------------------------------------
# Arena-level wrappers (stacked-layer leaves, pool-side use)
# ---------------------------------------------------------------------------


def gather_arena(pages: dict, table: jax.Array, buf_len: int) -> dict:
    """{leaf: (layers, P+1, H, page, d)} -> {leaf: (layers, rows, H,
    buf_len, d)} contiguous arena (all layers; tests / host-driven
    inspection — the model paths gather per layer inside the scan)."""
    return {kk: jax.vmap(lambda p: gather_layer(p, table, buf_len))(leaf)
            for kk, leaf in pages.items()}


def scatter_arena(pages: dict, table: jax.Array, arena: dict) -> dict:
    """Inverse of ``gather_arena`` for the leaves present in ``arena``."""
    out = dict(pages)
    for kk in arena:
        out[kk] = jax.vmap(
            lambda p, v: scatter_layer(p, table, v))(pages[kk], arena[kk])
    return out


def replicate_rows(pages: dict, table: jax.Array,
                   row_src: jax.Array) -> dict:
    """Paged analogue of the arena-wide rollback gather (DESIGN.md §7):
    row ``i``'s chain CONTENT becomes row ``row_src[i]``'s, copied page
    by page through the table — chains keep their own physical pages
    (rows diverge again next round), only the bytes are replicated.
    Rows of one slot always hold equal-length chains (reservation is
    slot-wide), so source and destination entries are mapped in
    lockstep; unmapped destinations drop."""
    rows, n_lp = table.shape
    src_idx = jnp.take(table, row_src, axis=0).reshape(-1)
    dst = table.reshape(-1)

    def one(leaf):
        p1 = leaf.shape[0]
        vals = jnp.take(leaf, src_idx, axis=0)
        safe = jnp.where(dst > 0, dst, p1)   # unmapped -> OOB -> dropped
        return leaf.at[safe].set(vals, mode="drop")

    return {kk: jax.vmap(one)(leaf) for kk, leaf in pages.items()}


# Jitted pool-side entry points (static buf_len keeps the view slice a
# compile-time shape; jax.jit caches per (shapes, buf_len)).
gather_arena_jit = jax.jit(gather_arena, static_argnames=("buf_len",))
scatter_arena_jit = jax.jit(scatter_arena)
replicate_rows_jit = jax.jit(replicate_rows)


def paged_block(block_fn, table: jax.Array, buf_len: int):
    """Adapt a per-layer block function (``fn(params_l, carry, cache_l)
    -> (carry, new_cache_l)`` over a contiguous layer cache) to paged
    storage: gather the layer view, run the block unchanged, scatter the
    updated leaves back through the table.  This is what keeps paged
    attention bit-identical to the contiguous path — the block itself
    never sees a page."""

    def wrapped(params_l, carry, pages_l):
        view = {kk: gather_layer(pages_l[kk], table, buf_len)
                for kk in pages_l}
        carry2, new_view = block_fn(params_l, carry, view)
        new_pages = dict(pages_l)
        for kk in new_view:
            new_pages[kk] = scatter_layer(pages_l[kk], table, new_view[kk])
        return carry2, new_pages

    return wrapped
