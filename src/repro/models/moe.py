"""Mixture-of-Experts family (mixtral-8x22b: 8e top-2 + sliding-window
attention; granite-moe-1b-a400m: 32e top-8).

Routing is capacity-based with dispatch/combine einsums (GSPMD/MaxText
style) so the compiled FLOPs reflect *activated* expert compute
(top_k/E of dense), not an all-experts dense pass — this is what makes
the MoE roofline entries honest.  Attention/cache code is shared with the
dense family.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.stack import scan_blocks, stack_init

CAPACITY_FACTOR = 1.25        # training: standard dropped-token routing
SERVING_CAPACITY_FACTOR = 2.0  # serving: effectively dropless for balanced
                               # routers, keeping prefill/decode consistent
ROUTING_GROUP = 256  # tokens per routing group; bounds dispatch-tensor size


def _expert_init(key, cfg: ModelConfig) -> dict:
    def one(k):
        return L.swiglu_params(k, cfg.d_model, cfg.d_ff, cfg.activation_dtype)
    return jax.vmap(one)(jax.random.split(key, cfg.num_experts))


def _block_init(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    hd = cfg.resolved_head_dim
    return {
        "attn_norm": L.rmsnorm_params(cfg.d_model, cfg.activation_dtype),
        "attn": L.attn_params(k1, cfg.d_model, cfg.num_heads, cfg.kv_heads,
                              hd, cfg.activation_dtype),
        "mlp_norm": L.rmsnorm_params(cfg.d_model, cfg.activation_dtype),
        "router": L.dense_init(k2, cfg.d_model, cfg.num_experts, jnp.float32),
        "experts": _expert_init(k3, cfg),
    }


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    dt = cfg.activation_dtype
    return {
        "embed": L.embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dt),
        "layers": stack_init(k_layers, cfg.num_layers,
                             lambda k: _block_init(k, cfg)),
        "final_norm": L.rmsnorm_params(cfg.d_model, dt),
        "lm_head": L.dense_init(k_head, cfg.d_model, cfg.padded_vocab, dt),
    }


def _group_size(num_tokens: int) -> int:
    g = min(num_tokens, ROUTING_GROUP)
    while num_tokens % g:
        g -= 1
    return g


def capacity(cfg: ModelConfig, group: int, cf: float) -> int:
    cap = int(group * cfg.experts_per_token * cf / cfg.num_experts)
    return min(max(cap, cfg.experts_per_token), group)


def moe_mlp(params_l: dict, cfg: ModelConfig, x: jax.Array,
            cf: float = CAPACITY_FACTOR):
    """Capacity-based top-k MoE with group-wise routing.

    x: (B, S, D).  Tokens are routed in groups of ``ROUTING_GROUP`` so the
    dispatch/combine one-hot tensors stay O(tokens * group * k) instead of
    O(tokens^2 * k).  Returns (out, aux_loss) with the standard
    load-balance loss (E * Σ_e f_e p_e).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    group = _group_size(t)
    g = t // group
    cap = capacity(cfg, group, cf)
    xt = x.reshape(g, group, d)

    # Router matmul consumes bf16 and emits f32 via preferred_element_type
    # so the sequence-parallel all-gather upstream stays bf16 (2x less ICI
    # traffic; Perf log: granite-moe train_4k, iteration A2).
    logits = jnp.einsum("gtd,de->gte", xt,
                        params_l["router"].astype(xt.dtype),
                        preferred_element_type=jnp.float32)        # (G,t,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                 # (G,t,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Position of each (token, choice) within its expert's buffer.
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)         # (G,t,k,E)
    flat = onehot.reshape(g, group * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(g, group, k, e)
    within_cap = pos_in_expert < cap

    cap_onehot = jax.nn.one_hot(pos_in_expert, cap, dtype=x.dtype)  # (G,t,k,E,C)
    keep = (onehot * within_cap).astype(x.dtype)[..., None]
    dispatch = jnp.sum(keep * cap_onehot, axis=2)                  # (G,t,E,C)
    combine = jnp.sum(
        keep * cap_onehot * gate_vals[..., None, None].astype(x.dtype), axis=2)

    expert_in = jnp.einsum("gtec,gtd->egcd", dispatch, xt)         # (E,G,C,D)
    w = params_l["experts"]
    gate = jax.nn.silu(jnp.einsum("egcd,edf->egcf", expert_in, w["w_gate"]))
    up = jnp.einsum("egcd,edf->egcf", expert_in, w["w_up"])
    expert_out = jnp.einsum("egcf,efd->egcd", gate * up, w["w_down"])
    out = jnp.einsum("gtec,egcd->gtd", combine, expert_out)

    frac_tokens = jnp.mean(jnp.sum(onehot, axis=2).astype(jnp.float32),
                           axis=(0, 1)) / k                        # (E,)
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return out.reshape(b, s, d), aux


def _block_train(params_l, carry, _cache, cfg: ModelConfig, chunked):
    x, positions, aux = carry
    from repro.sharding.context import constrain
    x = constrain(x, "layer_carry")
    h, _ = T._attn_full(params_l["attn"], cfg,
                        L.rmsnorm(params_l["attn_norm"], x, cfg.norm_eps),
                        positions, chunked)
    x = x + h
    m, aux_l = moe_mlp(params_l, cfg,
                       L.rmsnorm(params_l["mlp_norm"], x, cfg.norm_eps))
    return (x + m, positions, aux + aux_l), None


def forward(params: dict, cfg: ModelConfig, batch: dict, *,
            remat: bool = True, return_aux: bool = False,
            return_hidden: bool = False):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    fn = functools.partial(_block_train, cfg=cfg, chunked=s > 2048)
    (x, _, aux), _ = scan_blocks(params["layers"], (x, positions, 0.0),
                                 fn, remat=remat)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        if return_aux:
            return x, aux / cfg.num_layers
        return x
    logits = x @ params["lm_head"]
    if return_aux:
        return logits, aux / cfg.num_layers
    return logits


init_cache = T.init_cache
cache_len = T.cache_len


def _block_prefill(params_l, carry, cache_l, cfg: ModelConfig, chunked):
    x, positions = carry
    from repro.sharding.context import constrain
    x = constrain(x, "layer_carry")
    h, (k, v) = T._attn_full(params_l["attn"], cfg,
                             L.rmsnorm(params_l["attn_norm"], x, cfg.norm_eps),
                             positions, chunked)
    x = x + h
    m, _ = moe_mlp(params_l, cfg,
                   L.rmsnorm(params_l["mlp_norm"], x, cfg.norm_eps),
                   cf=SERVING_CAPACITY_FACTOR)
    x = x + m
    # Cache write: same ring logic as dense.
    t_cache = cache_l["k"].shape[2]
    s = k.shape[2]
    if s >= t_cache:
        tail = jax.lax.dynamic_slice_in_dim(k, s - t_cache, t_cache, axis=2)
        tail_v = jax.lax.dynamic_slice_in_dim(v, s - t_cache, t_cache, axis=2)
        shift = s % t_cache
        idx = (jnp.arange(t_cache) - shift) % t_cache
        new_k = tail[:, :, idx] if shift else tail
        new_v = tail_v[:, :, idx] if shift else tail_v
    else:
        new_k = jax.lax.dynamic_update_slice_in_dim(cache_l["k"], k, 0, axis=2)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache_l["v"], v, 0, axis=2)
    return (x, positions), {"k": new_k, "v": new_v}


def prefill(params: dict, cfg: ModelConfig, batch: dict, cache: dict):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    fn = functools.partial(_block_prefill, cfg=cfg, chunked=s > 2048)
    layer_cache = {"k": cache["k"], "v": cache["v"]}
    (x, _), new_cache = scan_blocks(params["layers"], (x, positions), fn,
                                    cache=layer_cache)
    x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = (x @ params["lm_head"])[:, 0]
    return logits, {"k": new_cache["k"], "v": new_cache["v"],
                    "pos": jnp.asarray(s, jnp.int32)}


def _block_decode(params_l, carry, cache_l, cfg: ModelConfig):
    x, pos = carry
    from repro.sharding.context import constrain
    x = constrain(x, "layer_carry")
    p = params_l["attn"]
    hd = cfg.resolved_head_dim
    xin = L.rmsnorm(params_l["attn_norm"], x, cfg.norm_eps)
    q, k, v = L.project_qkv(p, xin, cfg.num_heads, cfg.kv_heads, hd)
    posb = jnp.broadcast_to(pos[None, None], (x.shape[0], 1, 1))
    q = L.apply_rope(q, posb, cfg.rope_theta)
    k = L.apply_rope(k, posb, cfg.rope_theta)
    t_cache = cache_l["k"].shape[2]
    slot = pos % t_cache
    new_k = jax.lax.dynamic_update_slice_in_dim(cache_l["k"], k, slot, axis=2)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache_l["v"], v, slot, axis=2)
    kv_len = jnp.minimum(pos + 1, t_cache)
    out = L.attention(q, new_k, new_v, causal=False, kv_len=kv_len)
    x = x + L.project_out(p, out)
    m, _ = moe_mlp(params_l, cfg,
                   L.rmsnorm(params_l["mlp_norm"], x, cfg.norm_eps),
                   cf=SERVING_CAPACITY_FACTOR)
    return (x + m, pos), {"k": new_k, "v": new_v}


def decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array, cache: dict):
    x = params["embed"][tokens]
    pos = cache["pos"]
    fn = functools.partial(_block_decode, cfg=cfg)
    layer_cache = {"k": cache["k"], "v": cache["v"]}
    (x, _), new_cache = scan_blocks(params["layers"], (x, pos), fn,
                                    cache=layer_cache)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = (x @ params["lm_head"])[:, 0]
    return logits, {"k": new_cache["k"], "v": new_cache["v"], "pos": pos + 1}
