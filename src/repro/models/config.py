"""Model configuration dataclass shared by all architecture families."""

from __future__ import annotations

import dataclasses
import jax.numpy as jnp

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config describes any of the six supported families.

    Family-specific fields are zero/None when unused.  All sizes are the
    *published* sizes; ``padded_vocab`` rounds the embedding/logit dim up
    to a multiple of 256 for TPU lane alignment and mesh divisibility
    (e.g. whisper's 51865), with losses/samplers masking the pad region.
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    d_ff: int
    vocab_size: int
    num_kv_heads: int = 0
    head_dim: int = 0

    # Attention flavour.
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # 0 = full attention (mixtral: 4096)
    max_seq_len: int = 1 << 20

    # MoE.
    num_experts: int = 0
    experts_per_token: int = 0

    # SSM (mamba2 / SSD).
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # Hybrid (recurrentgemma): block pattern unit = (pattern_rec x RG-LRU,
    # 1 x local attention); local window size.
    pattern_rec: int = 0             # recurrent blocks per unit (rg: 2)
    local_window: int = 0            # rg: 2048
    lru_width: int = 0               # rg: d_model-ish recurrent width

    # Enc-dec (whisper): encoder depth + max decoder length.
    encoder_layers: int = 0
    max_decoder_len: int = 448

    # VLM (llama-3.2-vision): one cross-attn layer every `cross_attn_period`
    # self-attn layers; number of stubbed image patch embeddings.
    cross_attn_period: int = 0       # vision-11b: 5 (8 cross layers in 40)
    num_image_tokens: int = 0

    # Numerics.
    dtype: str = "bfloat16"          # activations / params for dry-run
    norm_eps: float = 1e-5

    # --- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def kv_heads(self) -> int:
        return self.num_kv_heads or self.num_heads

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.family != "ssm" and self.num_heads <= 0:
            raise ValueError(f"{self.name}: num_heads required")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant of the same family: tiny but structurally alike."""
        kw = dict(
            name=self.name + "-smoke",
            num_layers=2,
            d_model=min(self.d_model, 256),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            max_seq_len=4096,
            dtype="float32",
        )
        if self.num_heads:
            heads = min(self.num_heads, 4)
            kv = max(1, min(self.kv_heads, heads))
            while heads % kv:
                kv -= 1
            kw.update(num_heads=heads, num_kv_heads=kv, head_dim=64)
        if self.num_experts:
            kw.update(num_experts=min(self.num_experts, 4),
                      experts_per_token=min(self.experts_per_token, 2))
        if self.family == "ssm":
            kw.update(ssm_state=min(self.ssm_state, 16), ssm_head_dim=32,
                      ssm_chunk=32)
        if self.family == "hybrid":
            kw.update(num_layers=3, local_window=64,
                      lru_width=min(self.lru_width or self.d_model, 256))
        if self.family == "encdec":
            kw.update(encoder_layers=2, max_decoder_len=64)
        if self.family == "vlm":
            kw.update(num_layers=5, cross_attn_period=self.cross_attn_period,
                      num_image_tokens=16)
        if self.sliding_window:
            kw.update(sliding_window=64)
        return self.replace(**kw)
