"""Whisper-style encoder-decoder transformer backbone (arXiv:2212.04356).

The audio frontend (mel spectrogram + conv feature extractor) is a STUB
per the assignment: ``batch["frames"]`` carries precomputed frame
embeddings (B, S_enc, d_model).  This module implements the transformer
that consumes them: a bidirectional encoder + a causal decoder with
cross-attention.  Whisper uses LayerNorm, GELU MLPs, learned/sinusoidal
absolute positions (no RoPE) and full MHA (kv == heads).

Serving: ``prefill`` runs the encoder once, caches cross-attention K/V per
decoder layer, and prefills the decoder self-attention cache over
``batch["tokens"]``.  ``decode_step`` then extends one token at a time.
For decode_32k the long dimension is the *encoder* (cross-attn source) —
the mechanically faithful reading for enc-dec (see DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.stack import scan_blocks, stack_init

# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def sinusoids(length: int, channels: int) -> jax.Array:
    """Whisper's sinusoidal position embedding."""
    log_timescale = jnp.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2))
    scaled = jnp.arange(length)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


def _enc_block_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    dt = cfg.activation_dtype
    hd = cfg.resolved_head_dim
    return {
        "attn_norm": L.layernorm_params(cfg.d_model, dt),
        "attn": L.attn_params(k1, cfg.d_model, cfg.num_heads, cfg.kv_heads,
                              hd, dt),
        "mlp_norm": L.layernorm_params(cfg.d_model, dt),
        "mlp": L.gelu_mlp_params(k2, cfg.d_model, cfg.d_ff, dt),
    }


def _dec_block_init(key, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    dt = cfg.activation_dtype
    hd = cfg.resolved_head_dim
    return {
        "self_norm": L.layernorm_params(cfg.d_model, dt),
        "self_attn": L.attn_params(k1, cfg.d_model, cfg.num_heads,
                                   cfg.kv_heads, hd, dt),
        "cross_norm": L.layernorm_params(cfg.d_model, dt),
        "cross_attn": L.attn_params(k2, cfg.d_model, cfg.num_heads,
                                    cfg.kv_heads, hd, dt),
        "mlp_norm": L.layernorm_params(cfg.d_model, dt),
        "mlp": L.gelu_mlp_params(k3, cfg.d_model, cfg.d_ff, dt),
    }


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    k_emb, k_enc, k_dec = jax.random.split(key, 3)
    dt = cfg.activation_dtype
    return {
        "embed": L.embed_init(k_emb, cfg.padded_vocab, cfg.d_model, dt),
        "pos_dec": L.embed_init(jax.random.fold_in(k_emb, 1),
                                cfg.max_decoder_len, cfg.d_model, dt),
        "encoder": stack_init(k_enc, cfg.encoder_layers,
                              lambda k: _enc_block_init(k, cfg)),
        "enc_norm": L.layernorm_params(cfg.d_model, dt),
        "decoder": stack_init(k_dec, cfg.num_layers,
                              lambda k: _dec_block_init(k, cfg)),
        "dec_norm": L.layernorm_params(cfg.d_model, dt),
        # Whisper ties the LM head to the embedding; we do the same.
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------


def _enc_block(params_l, x, _cache, cfg: ModelConfig, chunked: bool):
    from repro.sharding.context import constrain
    x = constrain(x, "enc_carry")
    hd = cfg.resolved_head_dim
    xn = L.layernorm(params_l["attn_norm"], x, cfg.norm_eps)
    q, k, v = L.project_qkv(params_l["attn"], xn, cfg.num_heads,
                            cfg.kv_heads, hd)
    if chunked:
        out = L.chunked_attention(q, k, v, causal=False)
    else:
        out = L.attention(q, k, v, causal=False)
    x = x + L.project_out(params_l["attn"], out)
    x = x + L.gelu_mlp(params_l["mlp"],
                       L.layernorm(params_l["mlp_norm"], x, cfg.norm_eps))
    return x, None


def encode(params: dict, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, d_model) stub embeddings -> encoder states."""
    s = frames.shape[1]
    x = frames + sinusoids(s, cfg.d_model).astype(frames.dtype)[None]
    fn = functools.partial(_enc_block, cfg=cfg, chunked=s > 2048)
    x, _ = scan_blocks(params["encoder"], x, fn)
    return L.layernorm(params["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------


def _dec_positions(cfg: ModelConfig, pos):
    return jnp.clip(pos, 0, cfg.max_decoder_len - 1)


def _dec_block_full(params_l, carry, cache_l, cfg: ModelConfig,
                    enc_chunked: bool):
    """Full decoder pass (train / prefill).  carry = (x, enc)."""
    x, enc = carry
    from repro.sharding.context import constrain
    x = constrain(x, "layer_carry")
    hd = cfg.resolved_head_dim
    # Self attention (causal).
    xn = L.layernorm(params_l["self_norm"], x, cfg.norm_eps)
    q, k, v = L.project_qkv(params_l["self_attn"], xn, cfg.num_heads,
                            cfg.kv_heads, hd)
    out = L.attention(q, k, v, causal=True)
    x = x + L.project_out(params_l["self_attn"], out)
    # Cross attention to encoder states.
    xn = L.layernorm(params_l["cross_norm"], x, cfg.norm_eps)
    qc = (xn @ params_l["cross_attn"]["wq"]).reshape(
        x.shape[0], x.shape[1], cfg.num_heads, hd).transpose(0, 2, 1, 3)
    kc = (enc @ params_l["cross_attn"]["wk"]).reshape(
        enc.shape[0], enc.shape[1], cfg.kv_heads, hd).transpose(0, 2, 1, 3)
    vc = (enc @ params_l["cross_attn"]["wv"]).reshape(
        enc.shape[0], enc.shape[1], cfg.kv_heads, hd).transpose(0, 2, 1, 3)
    if enc_chunked:
        outc = L.chunked_attention(qc, kc, vc, causal=False)
    else:
        outc = L.attention(qc, kc, vc, causal=False)
    x = x + L.project_out(params_l["cross_attn"], outc)
    x = x + L.gelu_mlp(params_l["mlp"],
                       L.layernorm(params_l["mlp_norm"], x, cfg.norm_eps))
    new_cache = None
    if cache_l is not None:
        t_cache = cache_l["k"].shape[2]
        sk = jnp.minimum(k.shape[2], t_cache)
        new_cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache_l["k"], k[:, :, :t_cache], 0, axis=2),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache_l["v"], v[:, :, :t_cache], 0, axis=2),
            "ck": kc, "cv": vc,
        }
    return (x, enc), new_cache


def forward(params: dict, cfg: ModelConfig, batch: dict, *,
            remat: bool = True, return_hidden: bool = False) -> jax.Array:
    """Training forward: frames (B,S_enc,D) + tokens (B,S_dec) -> logits."""
    enc = encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    pos = jnp.arange(s)
    x = params["embed"][tokens] + params["pos_dec"][_dec_positions(cfg, pos)][None]
    fn = functools.partial(_dec_block_full, cfg=cfg,
                           enc_chunked=enc.shape[1] > 2048)
    (x, _), _ = scan_blocks(params["decoder"], (x, enc), fn, remat=remat)
    x = L.layernorm(params["dec_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x
    return x @ params["embed"].T


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """max_len here is the ENCODER length for enc-dec archs; the decoder
    self-cache is bounded by cfg.max_decoder_len."""
    hd = cfg.resolved_head_dim
    dt = cfg.activation_dtype
    t_dec = cfg.max_decoder_len
    return {
        "k": jnp.zeros((cfg.num_layers, batch, cfg.kv_heads, t_dec, hd), dt),
        "v": jnp.zeros((cfg.num_layers, batch, cfg.kv_heads, t_dec, hd), dt),
        "ck": jnp.zeros((cfg.num_layers, batch, cfg.kv_heads, max_len, hd), dt),
        "cv": jnp.zeros((cfg.num_layers, batch, cfg.kv_heads, max_len, hd), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params: dict, cfg: ModelConfig, batch: dict, cache: dict):
    enc = encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    pos = jnp.arange(s)
    x = params["embed"][tokens] + params["pos_dec"][_dec_positions(cfg, pos)][None]
    fn = functools.partial(_dec_block_full, cfg=cfg,
                           enc_chunked=enc.shape[1] > 2048)
    layer_cache = {"k": cache["k"], "v": cache["v"],
                   "ck": cache["ck"], "cv": cache["cv"]}
    (x, _), new_cache = scan_blocks(params["decoder"], (x, enc), fn,
                                    cache=layer_cache)
    x = L.layernorm(params["dec_norm"], x[:, -1:], cfg.norm_eps)
    logits = (x @ params["embed"].T)[:, 0]
    return logits, {**new_cache, "pos": jnp.asarray(s, jnp.int32)}


def _dec_block_step(params_l, carry, cache_l, cfg: ModelConfig):
    x, pos = carry
    from repro.sharding.context import constrain
    x = constrain(x, "layer_carry")
    hd = cfg.resolved_head_dim
    xn = L.layernorm(params_l["self_norm"], x, cfg.norm_eps)
    q, k, v = L.project_qkv(params_l["self_attn"], xn, cfg.num_heads,
                            cfg.kv_heads, hd)
    t_cache = cache_l["k"].shape[2]
    slot = jnp.minimum(pos, t_cache - 1)
    new_k = jax.lax.dynamic_update_slice_in_dim(cache_l["k"], k, slot, axis=2)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache_l["v"], v, slot, axis=2)
    kv_len = jnp.minimum(pos + 1, t_cache)
    out = L.attention(q, new_k, new_v, causal=False, kv_len=kv_len)
    x = x + L.project_out(params_l["self_attn"], out)
    # Cross attention against the prefilled encoder cache.
    xn = L.layernorm(params_l["cross_norm"], x, cfg.norm_eps)
    qc = (xn @ params_l["cross_attn"]["wq"]).reshape(
        x.shape[0], 1, cfg.num_heads, hd).transpose(0, 2, 1, 3)
    # Single-token cross-attention: scores are (B, H, 1, T) — small even
    # at 32k T, and the plain path lets XLA do one partial-softmax
    # all-reduce over the model-sharded T instead of per-block collectives
    # in a scanned chunk loop (Perf log: whisper decode_32k, iteration C1).
    outc = L.attention(qc, cache_l["ck"], cache_l["cv"], causal=False)
    x = x + L.project_out(params_l["cross_attn"], outc)
    x = x + L.gelu_mlp(params_l["mlp"],
                       L.layernorm(params_l["mlp_norm"], x, cfg.norm_eps))
    return (x, pos), {"k": new_k, "v": new_v,
                      "ck": cache_l["ck"], "cv": cache_l["cv"]}


def decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array, cache: dict):
    pos = cache["pos"]
    x = (params["embed"][tokens]
         + params["pos_dec"][_dec_positions(cfg, pos)][None, None])
    fn = functools.partial(_dec_block_step, cfg=cfg)
    layer_cache = {"k": cache["k"], "v": cache["v"],
                   "ck": cache["ck"], "cv": cache["cv"]}
    (x, _), new_cache = scan_blocks(params["decoder"], (x, pos), fn,
                                    cache=layer_cache)
    x = L.layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = (x @ params["embed"].T)[:, 0]
    return logits, {**new_cache, "pos": pos + 1}
