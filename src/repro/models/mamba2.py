"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060).  Attention-free.

Training/prefill uses the *chunked* SSD algorithm: intra-chunk quadratic
(attention-like, decay-masked) + inter-chunk diagonal state recurrence, so
materialized states are O(seq/chunk), not O(seq).  Decode is the O(1)
per-token recurrence — which is why this arch runs the long_500k shape.

Per-block structure (simplified n_groups=1 Mamba-2):
  in_proj: d -> [z (d_in), x (d_in), B (d_state), C (d_state), dt (H)]
  depthwise causal conv(width 4) over [x, B, C]
  SSD: h_t = exp(A dt_t) h_{t-1} + dt_t * B_t (x) x_t ;  y_t = C_t . h_t + D x_t
  out = out_proj( rmsnorm(y * silu(z)) )
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.stack import scan_blocks, stack_init

# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_d_inner
    h = cfg.ssm_num_heads
    ds = cfg.ssm_state
    conv_dim = d_in + 2 * ds
    return d_in, h, ds, conv_dim


def _block_init(key, cfg: ModelConfig) -> dict:
    d_in, h, ds, conv_dim = _dims(cfg)
    dt = cfg.activation_dtype
    k1, k2, k3 = jax.random.split(key, 3)
    proj_out = 2 * d_in + 2 * ds + h
    return {
        "norm": L.rmsnorm_params(cfg.d_model, dt),
        "in_proj": L.dense_init(k1, cfg.d_model, proj_out, dt),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv_width, conv_dim),
                                     jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "y_norm": L.rmsnorm_params(d_in, dt),
        "out_proj": L.dense_init(k3, d_in, cfg.d_model, dt),
    }


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    dt = cfg.activation_dtype
    return {
        "embed": L.embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dt),
        "layers": stack_init(k_layers, cfg.num_layers,
                             lambda k: _block_init(k, cfg)),
        "final_norm": L.rmsnorm_params(cfg.d_model, dt),
        "lm_head": L.dense_init(k_head, cfg.d_model, cfg.padded_vocab, dt),
    }


# ---------------------------------------------------------------------------
# Depthwise causal conv1d
# ---------------------------------------------------------------------------


def causal_conv(w: jax.Array, b: jax.Array, x: jax.Array,
                state: jax.Array | None = None):
    """x: (B, S, C); w: (W, C) depthwise.  Returns (y, new_state) where
    state is the last (W-1) inputs for streaming decode."""
    width = w.shape[0]
    if state is None:
        x_pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        x_pad = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = jnp.zeros_like(x, dtype=jnp.float32)
    s = x.shape[1]
    for i in range(width):
        y = y + x_pad[:, i:i + s].astype(jnp.float32) * w[i].astype(jnp.float32)
    y = jax.nn.silu(y + b.astype(jnp.float32)).astype(x.dtype)
    new_state = x_pad[:, x_pad.shape[1] - (width - 1):]
    return y, new_state


# ---------------------------------------------------------------------------
# Chunked SSD core
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, a, b_in, c_in, chunk: int, h0=None):
    """Chunked SSD scan.

    Args:
      x:    (B, S, H, P)  per-head inputs (P = head_dim)
      dt:   (B, S, H)     softplus'd step sizes (float32)
      a:    (H,)          negative decay rates (float32, a < 0)
      b_in: (B, S, N)     input projections (shared across heads, n_groups=1)
      c_in: (B, S, N)     output projections
      chunk: chunk length Q (static; S % Q == 0 after padding)
      h0:   optional initial state (B, H, P, N)

    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    q = chunk
    if s % q:
        pad = q - s % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
        s_pad = s + pad
    else:
        s_pad = s
    nc = s_pad // q

    xs = x.reshape(bsz, nc, q, h, p)
    dts = dt.reshape(bsz, nc, q, h)
    bs = b_in.reshape(bsz, nc, q, n)
    cs = c_in.reshape(bsz, nc, q, n)

    # Per-step log decay and within-chunk cumulative sums.
    la = dts * a[None, None, None, :]                    # (B,NC,Q,H) log decay
    cum = jnp.cumsum(la, axis=2)                         # inclusive cumsum
    total = cum[:, :, -1]                                # (B,NC,H)

    # ---- intra-chunk (quadratic, decay-masked) ----
    # L[i,j] = exp(cum_i - cum_j) for i >= j else 0   (note: includes la_i)
    li = cum[:, :, :, None, :]                           # (B,NC,Q,1,H)
    lj = cum[:, :, None, :, :]                           # (B,NC,1,Q,H)
    mask = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    # Double-where: masked (i<j) entries have li-lj > 0 which would overflow
    # exp and poison gradients with inf*0=NaN cotangents.
    diff = jnp.where(mask, li - lj, 0.0)
    decay = jnp.where(mask, jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", cs.astype(jnp.float32),
                    bs.astype(jnp.float32))              # (B,NC,Q,Q)
    w = cb[..., None] * decay                            # (B,NC,Q,Q,H)
    xdt = xs.astype(jnp.float32) * dts[..., None]        # (B,NC,Q,H,P)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xdt)

    # ---- chunk summary states ----
    # state_c = Σ_j exp(total - cum_j) * B_j ⊗ (dt_j x_j)   (B,NC,H,P,N)
    rem = jnp.exp(total[:, :, None, :] - cum)            # (B,NC,Q,H)
    states = jnp.einsum("bcjh,bcjn,bcjhp->bchpn",
                        rem, bs.astype(jnp.float32), xdt)

    # ---- inter-chunk recurrence over chunk boundaries ----
    def step(h_prev, xs_c):
        tot_c, st_c = xs_c                               # (B,H), (B,H,P,N)
        h_in = h_prev                                    # state entering chunk
        h_out = h_prev * jnp.exp(tot_c)[:, :, None, None] + st_c
        return h_out, h_in

    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    tot_t = total.transpose(1, 0, 2)                     # (NC,B,H)
    st_t = states.transpose(1, 0, 2, 3, 4)               # (NC,B,H,P,N)
    h_final, h_ins = jax.lax.scan(step, h0.astype(jnp.float32), (tot_t, st_t))
    h_ins = h_ins.transpose(1, 0, 2, 3, 4)               # (B,NC,H,P,N)

    # ---- inter-chunk output: y_t += exp(cum_t) * C_t . h_in ----
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp",
                         cs.astype(jnp.float32), h_ins, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(bsz, s_pad, h, p)[:, :s]
    return y.astype(x.dtype), h_final


def ssd_step(x, dt, a, b_in, c_in, h_prev):
    """Single-token recurrence.  x: (B,H,P); dt: (B,H); b/c: (B,N);
    h_prev: (B,H,P,N) -> (y (B,H,P), h (B,H,P,N))."""
    decay = jnp.exp(dt * a[None, :])                         # (B,H)
    dx = (x * dt[..., None]).astype(jnp.float32)             # (B,H,P)
    h = (h_prev * decay[:, :, None, None]
         + jnp.einsum("bhp,bn->bhpn", dx, b_in.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bn->bhp", h, c_in.astype(jnp.float32))
    return y.astype(x.dtype), h


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    d_in, h, ds, _ = _dims(cfg)
    z, xx, b_in, c_in, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + ds, 2 * d_in + 2 * ds], axis=-1)
    return z, xx, b_in, c_in, dt


def _block_apply(params_l, x, cfg: ModelConfig, cache_l=None):
    """Full-sequence path (train/prefill).  Returns (x, new_cache_l)."""
    d_in, h, ds, conv_dim = _dims(cfg)
    p = d_in // h
    res = x
    xn = L.rmsnorm(params_l["norm"], x, cfg.norm_eps)
    proj = xn @ params_l["in_proj"]
    z, xx, b_in, c_in, dt = _split_proj(cfg, proj)

    conv_in = jnp.concatenate([xx, b_in, c_in], axis=-1)
    conv_out, conv_state = causal_conv(params_l["conv_w"], params_l["conv_b"],
                                       conv_in)
    xx, b_in, c_in = jnp.split(conv_out, [d_in, d_in + ds], axis=-1)

    bsz, s, _ = x.shape
    xh = xx.reshape(bsz, s, h, p)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params_l["dt_bias"][None, None, :])
    a = -jnp.exp(params_l["a_log"])
    y, h_final = ssd_chunked(xh, dt, a, b_in, c_in, cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32).astype(y.dtype) * params_l["d_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, d_in)
    y = L.rmsnorm(params_l["y_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = res + y @ params_l["out_proj"]
    new_cache = None
    if cache_l is not None:
        new_cache = {"conv": conv_state.astype(cache_l["conv"].dtype),
                     "ssm": h_final.astype(cache_l["ssm"].dtype)}
    return out, new_cache


def forward(params: dict, cfg: ModelConfig, batch: dict, *,
            remat: bool = True, return_hidden: bool = False) -> jax.Array:
    tokens = batch["tokens"]
    x = params["embed"][tokens]
    fn = functools.partial(_fn_train, cfg=cfg)
    x, _ = scan_blocks(params["layers"], x, fn, remat=remat)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x
    return x @ params["lm_head"]


def _fn_train(params_l, x, _cache, cfg: ModelConfig):
    from repro.sharding.context import constrain
    x = constrain(x, "layer_carry")
    out, _ = _block_apply(params_l, x, cfg)
    return out, None


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    d_in, h, ds, conv_dim = _dims(cfg)
    p = d_in // h
    dt = cfg.activation_dtype
    return {
        "conv": jnp.zeros((cfg.num_layers, batch, cfg.ssm_conv_width - 1,
                           conv_dim), dt),
        "ssm": jnp.zeros((cfg.num_layers, batch, h, p, ds), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def _fn_prefill(params_l, x, cache_l, cfg: ModelConfig):
    from repro.sharding.context import constrain
    x = constrain(x, "layer_carry")
    return _block_apply(params_l, x, cfg, cache_l=cache_l)


def prefill(params: dict, cfg: ModelConfig, batch: dict, cache: dict):
    tokens = batch["tokens"]
    s = tokens.shape[1]
    x = params["embed"][tokens]
    fn = functools.partial(_fn_prefill, cfg=cfg)
    layer_cache = {"conv": cache["conv"], "ssm": cache["ssm"]}
    x, new_cache = scan_blocks(params["layers"], x, fn, cache=layer_cache)
    x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = (x @ params["lm_head"])[:, 0]
    return logits, {"conv": new_cache["conv"], "ssm": new_cache["ssm"],
                    "pos": jnp.asarray(s, jnp.int32)}


def _fn_decode(params_l, carry, cache_l, cfg: ModelConfig):
    x = carry  # (B, 1, D)
    from repro.sharding.context import constrain
    x = constrain(x, "layer_carry")
    d_in, h, ds, conv_dim = _dims(cfg)
    p = d_in // h
    res = x
    xn = L.rmsnorm(params_l["norm"], x, cfg.norm_eps)
    proj = xn @ params_l["in_proj"]
    z, xx, b_in, c_in, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xx, b_in, c_in], axis=-1)
    conv_out, conv_state = causal_conv(params_l["conv_w"], params_l["conv_b"],
                                       conv_in, state=cache_l["conv"])
    xx, b_in, c_in = jnp.split(conv_out, [d_in, d_in + ds], axis=-1)
    bsz = x.shape[0]
    xh = xx[:, 0].reshape(bsz, h, p)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + params_l["dt_bias"][None, :])
    a = -jnp.exp(params_l["a_log"])
    y, h_new = ssd_step(xh, dt, a, b_in[:, 0], c_in[:, 0], cache_l["ssm"])
    y = y + xh * params_l["d_skip"].astype(xh.dtype)[None, :, None]
    y = y.reshape(bsz, 1, d_in)
    y = L.rmsnorm(params_l["y_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = res + y @ params_l["out_proj"]
    return out, {"conv": conv_state.astype(cache_l["conv"].dtype),
                 "ssm": h_new}


def decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array, cache: dict):
    x = params["embed"][tokens]
    fn = functools.partial(_fn_decode, cfg=cfg)
    layer_cache = {"conv": cache["conv"], "ssm": cache["ssm"]}
    x, new_cache = scan_blocks(params["layers"], x, fn, cache=layer_cache)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = (x @ params["lm_head"])[:, 0]
    return logits, {"conv": new_cache["conv"], "ssm": new_cache["ssm"],
                    "pos": cache["pos"] + 1}
