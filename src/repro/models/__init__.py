"""Model substrate: six architecture families behind one API."""

from repro.models.config import ModelConfig
from repro.models.registry import (
    decode_step,
    family_module,
    forward,
    init_cache,
    init_params,
    param_count,
    prefill,
)

__all__ = [
    "ModelConfig",
    "decode_step",
    "family_module",
    "forward",
    "init_cache",
    "init_params",
    "param_count",
    "prefill",
]
