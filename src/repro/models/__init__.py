"""Model substrate: six architecture families behind one API."""

from repro.models.cache_pool import CachePool, PagedCachePool, \
    PagePoolExhausted
from repro.models.config import ModelConfig
from repro.models.registry import (
    decode_step,
    family_module,
    forward,
    init_cache,
    init_params,
    param_count,
    prefill,
)

from repro.models.transformer import (
    decode_step_slots,
    decode_step_slots_paged,
    prefill_slots,
    prefill_slots_paged,
    verify_step_slots,
    verify_step_slots_paged,
)

__all__ = [
    "CachePool",
    "PagedCachePool",
    "PagePoolExhausted",
    "ModelConfig",
    "decode_step",
    "decode_step_slots",
    "decode_step_slots_paged",
    "prefill_slots",
    "prefill_slots_paged",
    "verify_step_slots",
    "verify_step_slots_paged",
    "family_module",
    "forward",
    "init_cache",
    "init_params",
    "param_count",
    "prefill",
]
