"""Model substrate: six architecture families behind one API."""

from repro.models.cache_pool import CachePool
from repro.models.config import ModelConfig
from repro.models.registry import (
    decode_step,
    family_module,
    forward,
    init_cache,
    init_params,
    param_count,
    prefill,
)

from repro.models.transformer import (
    decode_step_slots,
    prefill_slots,
    verify_step_slots,
)

__all__ = [
    "CachePool",
    "ModelConfig",
    "decode_step",
    "decode_step_slots",
    "prefill_slots",
    "verify_step_slots",
    "family_module",
    "forward",
    "init_cache",
    "init_params",
    "param_count",
    "prefill",
]
