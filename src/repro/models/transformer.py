"""Dense llama-family decoder-only transformer (GQA + RoPE + SwiGLU +
RMSNorm).  Covers granite-8b/34b, llama3-405b, smollm-360m, and is the
backbone reused by the MoE and VLM families.

Uniform model API (same across all families; see registry.py):

  init_params(key, cfg)                         -> params
  forward(params, cfg, batch)                   -> logits (B, S, Vpad)
  init_cache(cfg, batch, max_len)               -> cache
  prefill(params, cfg, batch, cache)            -> (last_logits (B,Vpad), cache)
  decode_step(params, cfg, tokens (B,1), cache) -> (logits (B,Vpad), cache)

KV caches hold RoPE'd keys; sliding-window configs use a ring buffer of
size ``window`` so long_500k decode state stays O(window).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.stack import scan_blocks, stack_init

# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def _block_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    hd = cfg.resolved_head_dim
    return {
        "attn_norm": L.rmsnorm_params(cfg.d_model, cfg.activation_dtype),
        "attn": L.attn_params(k1, cfg.d_model, cfg.num_heads, cfg.kv_heads,
                              hd, cfg.activation_dtype),
        "mlp_norm": L.rmsnorm_params(cfg.d_model, cfg.activation_dtype),
        "mlp": L.swiglu_params(k2, cfg.d_model, cfg.d_ff, cfg.activation_dtype),
    }


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    dt = cfg.activation_dtype
    return {
        "embed": L.embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dt),
        "layers": stack_init(k_layers, cfg.num_layers,
                             lambda k: _block_init(k, cfg)),
        "final_norm": L.rmsnorm_params(cfg.d_model, dt),
        "lm_head": L.dense_init(k_head, cfg.d_model, cfg.padded_vocab, dt),
    }


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _attn_full(p, cfg: ModelConfig, x, positions, chunked: bool):
    """Full-sequence (train / prefill) self-attention."""
    hd = cfg.resolved_head_dim
    q, k, v = L.project_qkv(p, x, cfg.num_heads, cfg.kv_heads, hd)
    q = L.apply_rope(q, positions[:, None, :], cfg.rope_theta)
    k = L.apply_rope(k, positions[:, None, :], cfg.rope_theta)
    if chunked:
        out = L.chunked_attention(q, k, v, causal=True,
                                  window=cfg.sliding_window)
    else:
        out = L.attention(q, k, v, causal=True, window=cfg.sliding_window)
    return L.project_out(p, out), (k, v)


def _block_train(params_l, x_and_pos, _cache, cfg: ModelConfig, chunked):
    x, positions = x_and_pos
    from repro.sharding.context import constrain
    x = constrain(x, "layer_carry")
    h, _ = _attn_full(params_l["attn"], cfg,
                      L.rmsnorm(params_l["attn_norm"], x, cfg.norm_eps),
                      positions, chunked)
    x = x + h
    x = x + L.swiglu(params_l["mlp"],
                     L.rmsnorm(params_l["mlp_norm"], x, cfg.norm_eps))
    x = constrain(x, "layer_carry")
    return (x, positions), None


def forward(params: dict, cfg: ModelConfig, batch: dict, *,
            remat: bool = True, chunked: Optional[bool] = None,
            return_hidden: bool = False) -> jax.Array:
    tokens = batch["tokens"]
    b, s = tokens.shape
    if chunked is None:
        chunked = s > 2048
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    fn = functools.partial(_block_train, cfg=cfg, chunked=chunked)
    (x, _), _ = scan_blocks(params["layers"], (x, positions), fn, remat=remat)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x
    return L.dense(x, params["lm_head"])


# ---------------------------------------------------------------------------
# KV cache + serving paths
# ---------------------------------------------------------------------------


def cache_len(cfg: ModelConfig, max_len: int) -> int:
    return min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    t = cache_len(cfg, max_len)
    hd = cfg.resolved_head_dim
    dt = cfg.activation_dtype
    return {
        "k": jnp.zeros((cfg.num_layers, batch, cfg.kv_heads, t, hd), dt),
        "v": jnp.zeros((cfg.num_layers, batch, cfg.kv_heads, t, hd), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def _block_prefill(params_l, carry, cache_l, cfg: ModelConfig, chunked):
    """Prefill: full self-attention AND cache write (ring for SWA)."""
    x, positions = carry
    from repro.sharding.context import constrain
    x = constrain(x, "layer_carry")
    h, (k, v) = _attn_full(params_l["attn"], cfg,
                           L.rmsnorm(params_l["attn_norm"], x, cfg.norm_eps),
                           positions, chunked)
    x = x + h
    x = x + L.swiglu(params_l["mlp"],
                     L.rmsnorm(params_l["mlp_norm"], x, cfg.norm_eps))
    t_cache = cache_l["k"].shape[2]
    s = k.shape[2]
    if s >= t_cache:
        # Keep the last t_cache positions (ring semantics: slot = pos % t).
        tail = jax.lax.dynamic_slice_in_dim(k, s - t_cache, t_cache, axis=2)
        tail_v = jax.lax.dynamic_slice_in_dim(v, s - t_cache, t_cache, axis=2)
        shift = s % t_cache
        idx = (jnp.arange(t_cache) - shift) % t_cache
        new_k = tail[:, :, idx] if shift else tail
        new_v = tail_v[:, :, idx] if shift else tail_v
    else:
        new_k = jax.lax.dynamic_update_slice_in_dim(cache_l["k"], k, 0, axis=2)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache_l["v"], v, 0, axis=2)
    return (x, positions), {"k": new_k, "v": new_v}


def prefill(params: dict, cfg: ModelConfig, batch: dict, cache: dict):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    fn = functools.partial(_block_prefill, cfg=cfg, chunked=s > 2048)
    layer_cache = {"k": cache["k"], "v": cache["v"]}
    (x, _), new_cache = scan_blocks(params["layers"], (x, positions), fn,
                                    cache=layer_cache)
    x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = L.dense(x, params["lm_head"])[:, 0]
    return logits, {"k": new_cache["k"], "v": new_cache["v"],
                    "pos": jnp.asarray(s, jnp.int32)}


def _block_decode(params_l, carry, cache_l, cfg: ModelConfig):
    x, pos = carry  # x: (B, 1, D); pos: scalar current position
    from repro.sharding.context import constrain
    x = constrain(x, "layer_carry")
    p = params_l["attn"]
    hd = cfg.resolved_head_dim
    xin = L.rmsnorm(params_l["attn_norm"], x, cfg.norm_eps)
    q, k, v = L.project_qkv(p, xin, cfg.num_heads, cfg.kv_heads, hd)
    posb = jnp.broadcast_to(pos[None, None], (x.shape[0], 1, 1))
    q = L.apply_rope(q, posb, cfg.rope_theta)
    k = L.apply_rope(k, posb, cfg.rope_theta)
    t_cache = cache_l["k"].shape[2]
    slot = pos % t_cache
    new_k = jax.lax.dynamic_update_slice_in_dim(cache_l["k"], k, slot, axis=2)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache_l["v"], v, slot, axis=2)
    kv_len = jnp.minimum(pos + 1, t_cache)
    out = L.attention(q, new_k, new_v, causal=False, kv_len=kv_len)
    x = x + L.project_out(p, out)
    x = x + L.swiglu(params_l["mlp"],
                     L.rmsnorm(params_l["mlp_norm"], x, cfg.norm_eps))
    return (x, pos), {"k": new_k, "v": new_v}


def decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array, cache: dict):
    """tokens: (B, 1) -> (logits (B, Vpad), new cache)."""
    x = params["embed"][tokens]
    pos = cache["pos"]
    fn = functools.partial(_block_decode, cfg=cfg)
    layer_cache = {"k": cache["k"], "v": cache["v"]}
    (x, _), new_cache = scan_blocks(params["layers"], (x, pos), fn,
                                    cache=layer_cache)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.dense(x, params["lm_head"])[:, 0]
    return logits, {"k": new_cache["k"], "v": new_cache["v"], "pos": pos + 1}


def _maybe_quantize_kv(cache_l, k, v):
    """Quantize-on-write hook for int8 KV arenas (DESIGN.md §11): when the
    layer cache carries scale leaves (``k_s``/``v_s``), the freshly
    projected k/v quantize per KV vector and the caller writes int8 plus
    scales; otherwise k/v pass through and scales are None."""
    if "k_s" not in cache_l:
        return k, v, None, None
    from repro.serving.quant import quantize_kv
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    return kq, vq, ks, vs


def _rowwise_cache_write(cache_k, cache_v, k, v, starts):
    """Write each row's (H, m, hd) keys/values at its own time offset.
    cache_k/v: (B, H, T, hd); k/v: (B, H, m, hd); starts: (B,) i32."""
    upd = lambda c, kk, p: jax.lax.dynamic_update_slice_in_dim(
        c, kk, p, axis=1)
    return (jax.vmap(upd)(cache_k, k, starts),
            jax.vmap(upd)(cache_v, v, starts))


def _rowwise_cache_write_masked(cache_k, cache_v, k, v, starts, write):
    """Row-offset cache write that can skip rows: rows where ``write`` is
    False scatter to index T (out of bounds, ``mode="drop"``) so their
    cache content is untouched bit-for-bit.  Written rows land exactly
    where ``_rowwise_cache_write`` would put them.  cache_k/v:
    (B, H, T, hd); k/v: (B, H, m, hd); starts: (B,) i32; write: (B,)
    bool.  Chunk tails running past T (bucket padding near the buffer
    end) drop the same way."""
    t = cache_k.shape[2]
    m = k.shape[2]

    def upd(c, kk, p, w):
        idx = jnp.where(w, p + jnp.arange(m), t)   # t == OOB -> dropped
        return c.at[:, idx].set(kk, mode="drop")

    return (jax.vmap(upd)(cache_k, k, starts, write),
            jax.vmap(upd)(cache_v, v, starts, write))


def _block_prefill_slots(params_l, carry, cache_l, cfg: ModelConfig,
                         write, use_kernel: bool,
                         interpret: Optional[bool]):
    """Prompt-chunk prefill with per-row start positions, straight into a
    cache arena (the batched admission step, DESIGN.md §9).  Identical
    attention structure to ``_block_verify_slots`` — causal over the
    row's own cache prefix plus the freshly written chunk — with two
    differences: rows outside the admission wave are write-masked, and
    ``use_kernel`` routes the chunk attention through the
    ``kernels/flash_attention`` Pallas kernel."""
    x, pos = carry  # x: (B, m, D); pos: (B,) per-row chunk start position
    p = params_l["attn"]
    hd = cfg.resolved_head_dim
    b, m, _ = x.shape
    xin = L.rmsnorm(params_l["attn_norm"], x, cfg.norm_eps)
    q, k, v = L.project_qkv(p, xin, cfg.num_heads, cfg.kv_heads, hd)
    positions = pos[:, None, None] + jnp.arange(m, dtype=jnp.int32)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    k, v, ks, vs = _maybe_quantize_kv(cache_l, k, v)
    new_k, new_v = _rowwise_cache_write_masked(cache_l["k"], cache_l["v"],
                                               k, v, pos, write)
    new_cache = {"k": new_k, "v": new_v}
    k_scale = v_scale = None
    if ks is not None:
        k_scale, v_scale = _rowwise_cache_write_masked(
            cache_l["k_s"], cache_l["v_s"], ks, vs, pos, write)
        new_cache.update(k_s=k_scale, v_s=v_scale)
    out = L.attention(q, new_k, new_v, causal=True, q_offset=pos,
                      kv_len=pos + m, k_scale=k_scale, v_scale=v_scale,
                      use_kernel=use_kernel, interpret=interpret)
    x = x + L.project_out(p, out)
    x = x + L.swiglu(params_l["mlp"],
                     L.rmsnorm(params_l["mlp_norm"], x, cfg.norm_eps))
    return (x, pos), new_cache


def prefill_slots(params: dict, cfg: ModelConfig, tokens: jax.Array,
                  cache: dict, pos: jax.Array,
                  write: Optional[jax.Array] = None, *,
                  use_kernel: bool = False,
                  interpret: Optional[bool] = None) -> dict:
    """Device-side admission prefill: tokens (B, m) prompt chunks land
    directly in their arena rows at per-row offsets ``pos`` (B,) —
    no temporary cache, no host scatter (DESIGN.md §9).  Returns the new
    {k, v} arena; NO logits are computed (the lm_head matmul is the
    single largest flop term of a small-model admission and its output
    is discarded — the last prompt token stays *pending* and is scored
    by the first round's verify chunk instead).

    ``write`` (B,) bool masks rows outside the admission wave: their
    cache rows are bit-untouched and their (garbage) activations are
    discarded.  Rows shorter than the chunk are padded by the caller;
    pad KV lands above the row's live prefix, where every consumer
    overwrites before attending (§9 safety argument).  Non-ring caches
    only."""
    assert not cfg.sliding_window, "prefill_slots: non-ring caches only"
    x = params["embed"][tokens]
    if write is None:
        write = jnp.ones((tokens.shape[0],), bool)
    fn = functools.partial(_block_prefill_slots, cfg=cfg, write=write,
                           use_kernel=use_kernel, interpret=interpret)
    layer_cache = {kk: cache[kk] for kk in cache if kk != "pos"}
    (_, _), new_cache = scan_blocks(params["layers"], (x, pos), fn,
                                    cache=layer_cache)
    return dict(new_cache)


def _block_decode_slots(params_l, carry, cache_l, cfg: ModelConfig,
                        use_kernel: bool = False,
                        interpret: Optional[bool] = None):
    """Single-token decode where every batch row sits at its own position
    (cache-arena serving: rows = slots x drafts, DESIGN.md §7)."""
    x, pos = carry  # x: (B, 1, D); pos: (B,) per-row current position
    from repro.sharding.context import constrain
    x = constrain(x, "layer_carry")
    p = params_l["attn"]
    hd = cfg.resolved_head_dim
    xin = L.rmsnorm(params_l["attn_norm"], x, cfg.norm_eps)
    q, k, v = L.project_qkv(p, xin, cfg.num_heads, cfg.kv_heads, hd)
    posb = pos[:, None, None]                        # (B, 1, 1)
    q = L.apply_rope(q, posb, cfg.rope_theta)
    k = L.apply_rope(k, posb, cfg.rope_theta)
    t_cache = cache_l["k"].shape[2]
    k, v, ks, vs = _maybe_quantize_kv(cache_l, k, v)
    new_k, new_v = _rowwise_cache_write(cache_l["k"], cache_l["v"], k, v,
                                        pos % t_cache)
    new_cache = {"k": new_k, "v": new_v}
    k_scale = v_scale = None
    if ks is not None:
        k_scale, v_scale = _rowwise_cache_write(
            cache_l["k_s"], cache_l["v_s"], ks, vs, pos % t_cache)
        new_cache.update(k_s=k_scale, v_s=v_scale)
    kv_len = jnp.minimum(pos + 1, t_cache)
    out = L.attention(q, new_k, new_v, causal=False, kv_len=kv_len,
                      k_scale=k_scale, v_scale=v_scale,
                      use_kernel=use_kernel, interpret=interpret)
    x = x + L.project_out(p, out)
    x = x + L.swiglu(params_l["mlp"],
                     L.rmsnorm(params_l["mlp_norm"], x, cfg.norm_eps))
    return (x, pos), new_cache


def decode_step_slots(params: dict, cfg: ModelConfig, tokens: jax.Array,
                      cache: dict, pos: jax.Array, *,
                      use_kernel: bool = False,
                      interpret: Optional[bool] = None):
    """Per-row-position decode: tokens (B, 1), pos (B,) -> (logits
    (B, Vpad), new {k, v} cache).  Position tracking lives with the
    caller (host-side in the cache pool), not in the cache dict.
    ``use_kernel`` streams the per-row attention through the Pallas
    decode-attention kernel (numerically equivalent, not bit-equal)."""
    x = params["embed"][tokens]
    fn = functools.partial(_block_decode_slots, cfg=cfg,
                           use_kernel=use_kernel, interpret=interpret)
    layer_cache = {kk: cache[kk] for kk in cache if kk != "pos"}
    (x, _), new_cache = scan_blocks(params["layers"], (x, pos), fn,
                                    cache=layer_cache)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.dense(x, params["lm_head"])[:, 0]
    return logits, dict(new_cache)


def _block_verify(params_l, carry, cache_l, cfg: ModelConfig):
    """Multi-token decode ("verify chunk"): process m draft tokens against
    the cache in one pass — the serving step for multi-draft speculative
    decoding (paper Alg. 2).  Non-ring caches only (full attention)."""
    x, pos = carry  # x: (B, m, D); pos: scalar start position
    p = params_l["attn"]
    hd = cfg.resolved_head_dim
    b, m, _ = x.shape
    xin = L.rmsnorm(params_l["attn_norm"], x, cfg.norm_eps)
    q, k, v = L.project_qkv(p, xin, cfg.num_heads, cfg.kv_heads, hd)
    positions = (pos + jnp.arange(m, dtype=jnp.int32))[None, None, :]
    positions = jnp.broadcast_to(positions, (b, 1, m))
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    new_k = jax.lax.dynamic_update_slice_in_dim(cache_l["k"], k, pos, axis=2)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache_l["v"], v, pos, axis=2)
    kv_len = pos + m
    out = L.attention(q, new_k, new_v, causal=True, q_offset=pos,
                      kv_len=kv_len)
    x = x + L.project_out(p, out)
    x = x + L.swiglu(params_l["mlp"],
                     L.rmsnorm(params_l["mlp_norm"], x, cfg.norm_eps))
    return (x, pos), {"k": new_k, "v": new_v}


def verify_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                cache: dict):
    """tokens: (B, m) — the pending token + m-1 draft tokens.  Returns
    (logits (B, m, Vpad), new cache) with logits[:, j] = q(. | ...tokens
    up to j), i.e. the q^(1..m) distributions Algorithm 2 verifies."""
    assert not cfg.sliding_window, "verify_step: non-ring caches only"
    x = params["embed"][tokens]
    pos = cache["pos"]
    fn = functools.partial(_block_verify, cfg=cfg)
    layer_cache = {"k": cache["k"], "v": cache["v"]}
    (x, _), new_cache = scan_blocks(params["layers"], (x, pos), fn,
                                    cache=layer_cache)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.dense(x, params["lm_head"])
    return logits, {"k": new_cache["k"], "v": new_cache["v"],
                    "pos": pos + tokens.shape[1]}


def _block_verify_slots(params_l, carry, cache_l, cfg: ModelConfig):
    """Multi-token verify chunk with per-row start positions (the batched
    cache-arena step: rows of different requests verify their own drafts
    at their own offsets in one forward, DESIGN.md §7)."""
    x, pos = carry  # x: (B, m, D); pos: (B,) per-row start position
    p = params_l["attn"]
    hd = cfg.resolved_head_dim
    b, m, _ = x.shape
    xin = L.rmsnorm(params_l["attn_norm"], x, cfg.norm_eps)
    q, k, v = L.project_qkv(p, xin, cfg.num_heads, cfg.kv_heads, hd)
    positions = pos[:, None, None] + jnp.arange(m, dtype=jnp.int32)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    k, v, ks, vs = _maybe_quantize_kv(cache_l, k, v)
    new_k, new_v = _rowwise_cache_write(cache_l["k"], cache_l["v"], k, v,
                                        pos)
    new_cache = {"k": new_k, "v": new_v}
    k_scale = v_scale = None
    if ks is not None:
        k_scale, v_scale = _rowwise_cache_write(
            cache_l["k_s"], cache_l["v_s"], ks, vs, pos)
        new_cache.update(k_s=k_scale, v_s=v_scale)
    out = L.attention(q, new_k, new_v, causal=True, q_offset=pos,
                      kv_len=pos + m, k_scale=k_scale, v_scale=v_scale)
    x = x + L.project_out(p, out)
    x = x + L.swiglu(params_l["mlp"],
                     L.rmsnorm(params_l["mlp_norm"], x, cfg.norm_eps))
    return (x, pos), new_cache


def verify_step_slots(params: dict, cfg: ModelConfig, tokens: jax.Array,
                      cache: dict, pos: jax.Array):
    """Per-row-position verify chunk: tokens (B, m), pos (B,) -> (logits
    (B, m, Vpad), new {k, v} cache).  Row b's logits[:, j] are
    q(. | row-b cache prefix, tokens[b, :j+1]) — the Algorithm-2 target
    rows for a whole cache arena in ONE forward.  Non-ring caches only."""
    assert not cfg.sliding_window, "verify_step_slots: non-ring caches only"
    x = params["embed"][tokens]
    fn = functools.partial(_block_verify_slots, cfg=cfg)
    layer_cache = {kk: cache[kk] for kk in cache if kk != "pos"}
    (x, _), new_cache = scan_blocks(params["layers"], (x, pos), fn,
                                    cache=layer_cache)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.dense(x, params["lm_head"])
    return logits, dict(new_cache)


# ---------------------------------------------------------------------------
# Paged-arena serving paths (DESIGN.md §12)
# ---------------------------------------------------------------------------
#
# Same slot-aware serving steps, but the KV lives in fixed-size pages
# behind a per-row page table (models/paged.py) instead of one
# contiguous arena.  Each wrapper scans the SAME per-layer block
# function as its contiguous twin through ``paged.paged_block``: the
# layer's contiguous view is gathered from its pages, the block runs
# unchanged (identical reduction shapes — ``buf_len`` is the compiled
# view length), and the updated leaves scatter back through the table.
# Only one layer's view is ever materialized, and the attention math is
# bit-identical to the contiguous arena by construction.


def prefill_slots_paged(params: dict, cfg: ModelConfig, tokens: jax.Array,
                        pages: dict, table: jax.Array, pos: jax.Array,
                        write: Optional[jax.Array] = None, *,
                        buf_len: int, use_kernel: bool = False,
                        interpret: Optional[bool] = None) -> dict:
    """``prefill_slots`` against paged storage: pages {leaf: (layers,
    P+1, H, page, d)}, table (rows, n_lp) -> new pages.  The caller must
    have reserved pages covering ``pos + m`` tokens for written rows;
    masked rows' writes drop through their unmapped entries."""
    from repro.models import paged
    assert not cfg.sliding_window, "prefill_slots_paged: non-ring only"
    x = params["embed"][tokens]
    if write is None:
        write = jnp.ones((tokens.shape[0],), bool)
    inner = functools.partial(_block_prefill_slots, cfg=cfg, write=write,
                              use_kernel=use_kernel, interpret=interpret)
    fn = paged.paged_block(inner, table, buf_len)
    (_, _), new_pages = scan_blocks(params["layers"], (x, pos), fn,
                                    cache=dict(pages))
    return dict(new_pages)


def decode_step_slots_paged(params: dict, cfg: ModelConfig,
                            tokens: jax.Array, pages: dict,
                            table: jax.Array, pos: jax.Array, *,
                            buf_len: int, use_kernel: bool = False,
                            interpret: Optional[bool] = None):
    """``decode_step_slots`` against paged storage -> (logits (B, Vpad),
    new pages)."""
    from repro.models import paged
    x = params["embed"][tokens]
    inner = functools.partial(_block_decode_slots, cfg=cfg,
                              use_kernel=use_kernel, interpret=interpret)
    fn = paged.paged_block(inner, table, buf_len)
    (x, _), new_pages = scan_blocks(params["layers"], (x, pos), fn,
                                    cache=dict(pages))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.dense(x, params["lm_head"])[:, 0]
    return logits, dict(new_pages)


def verify_step_slots_paged(params: dict, cfg: ModelConfig,
                            tokens: jax.Array, pages: dict,
                            table: jax.Array, pos: jax.Array, *,
                            buf_len: int):
    """``verify_step_slots`` against paged storage -> (logits
    (B, m, Vpad), new pages)."""
    from repro.models import paged
    assert not cfg.sliding_window, "verify_step_slots_paged: non-ring only"
    x = params["embed"][tokens]
    inner = functools.partial(_block_verify_slots, cfg=cfg)
    fn = paged.paged_block(inner, table, buf_len)
    (x, _), new_pages = scan_blocks(params["layers"], (x, pos), fn,
                                    cache=dict(pages))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.dense(x, params["lm_head"])
    return logits, dict(new_pages)
