"""Shared neural-net layers: norms, RoPE, GQA attention (chunked online
softmax for long prefill), SwiGLU MLP.  Pure JAX, param pytrees are dicts.

Attention memory note: a naive (S x S) score matrix at 32k/500k sequence
lengths is the thing that blows the roofline memory term, so
``chunked_attention`` streams KV blocks with an online-softmax carry —
the jnp analogue of the flash-attention Pallas kernel in
``repro/kernels/flash_attention.py`` (which is the TPU-target version of
the same loop).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = 1.0 / jnp.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def dense(x: jax.Array, w) -> jax.Array:
    """Matmul that dispatches on the weight leaf: plain arrays use ``@``;
    ``{"q", "s"}`` dicts (``serving.quant.quantize_params``) route through
    the W8A8 ``qdot`` — so every layer below serves both f32 and int8
    param trees from one code path."""
    if isinstance(w, dict):
        from repro.serving.quant import qdot
        return qdot(x, w)
    return x @ w


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_params(dim: int, dtype) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_params(dim: int, dtype) -> dict:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, D) with D even; positions: (..., S) int32."""
    freqs = rope_frequencies(x.shape[-1], theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B, Hkv, G, S, D), k: (B, Hkv, T, D) -> (B, Hkv, G, S, T)."""
    return jnp.einsum("bhgsd,bhtd->bhgst", q, k, preferred_element_type=jnp.float32)


def _gqa_values(w: jax.Array, v: jax.Array) -> jax.Array:
    return jnp.einsum("bhgst,bhtd->bhgsd", w.astype(v.dtype), v)


def attention(
    q: jax.Array,                # (B, H, S, D)
    k: jax.Array,                # (B, Hkv, T, D)
    v: jax.Array,                # (B, Hkv, T, D)
    *,
    causal: bool = True,
    q_offset=0,                  # position of q[0]; scalar or per-row (B,)
    window: int = 0,             # sliding window (0 = unbounded)
    kv_len: Optional[jax.Array] = None,  # valid KV prefix length (decode);
                                         # scalar or per-row (B,)
    k_scale: Optional[jax.Array] = None,  # (B, Hkv, T, 1) int8-KV dequant
    v_scale: Optional[jax.Array] = None,  # scales, both or neither
    use_kernel: bool = False,    # route the decode case through Pallas
    interpret: Optional[bool] = None,  # tri-state (see resolve_pallas_mode)
) -> jax.Array:
    """GQA attention without materializing repeated KV heads.

    Small/medium sequence path; for long prefill use ``chunked_attention``.
    Per-row ``q_offset`` / ``kv_len`` support cache arenas where each
    batch row sits at its own decode position (DESIGN.md §7); the scalar
    path computes the identical masked scores it always did.

    ``use_kernel`` routes two cases through Pallas:

      * the single-query decode case (s == 1, non-causal, windowless,
        ``kv_len``-masked — exactly the slot-aware decode step) through
        the ``kernels/decode_attention`` kernel;
      * the causal multi-token case (s > 1, windowless, with per-row
        ``q_offset``/``kv_len`` arena masks — the admission prefill
        chunks of ``transformer.prefill_slots``) through the
        ``kernels/flash_attention`` kernel.

    Both are online-softmax streams over KV tiles, numerically
    equivalent to the dense path but not bit-equal (different reduction
    order), so they stay opt-in where bit-identity contracts apply.

    int8 KV arenas pass ``k_scale``/``v_scale`` (DESIGN.md §11): kernel
    routes dequantize in-kernel tile by tile; the dense path dequantizes
    up front.
    """
    b, h, s, d = q.shape
    if (use_kernel and s == 1 and not causal and not window
            and kv_len is not None):
        from repro.kernels.decode_attention.ops import decode_attention_op
        kvl = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,))
        out = decode_attention_op(q[:, :, 0], k, v, kvl, k_scale, v_scale,
                                  interpret=interpret)
        return out[:, :, None, :]
    if use_kernel and s > 1 and causal and not window:
        from repro.kernels.flash_attention.ops import flash_attention_op
        qo = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32).reshape(-1),
                              (b,))
        kvl = (None if kv_len is None else jnp.broadcast_to(
            jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,)))
        return flash_attention_op(q, k, v, qo, kvl, k_scale, v_scale,
                                  causal=True, interpret=interpret)
    out_dtype = q.dtype
    if k_scale is not None:
        k = k.astype(jnp.float32) * k_scale
        v = v.astype(jnp.float32) * v_scale
    hkv = k.shape[1]
    g = h // hkv
    q = q.reshape(b, hkv, g, s, d)
    scores = _gqa_scores(q, k) / jnp.sqrt(d).astype(jnp.float32)
    t = k.shape[2]
    q_off = jnp.asarray(q_offset)
    # Rows dim of the mask: 1 (shared mask, broadcast) or B (per-row).
    q_pos = q_off.reshape(-1, 1) + jnp.arange(s)          # (1 or B, S)
    k_pos = jnp.arange(t)
    rows = q_pos.shape[0]
    if kv_len is not None:
        kvl = jnp.asarray(kv_len).reshape(-1, 1, 1)       # (1 or B, 1, 1)
        rows = max(rows, kvl.shape[0])
    mask = jnp.ones((rows, s, t), bool)
    if causal:
        mask &= k_pos[None, None, :] <= q_pos[:, :, None]
    if window:
        mask &= k_pos[None, None, :] > q_pos[:, :, None] - window
    if kv_len is not None:
        mask &= k_pos[None, None, :] < kvl
    scores = jnp.where(mask[:, None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    # Rows that are fully masked produce NaN; zero them (can't happen for
    # causal q_offset>=0 but can for padded decode batches).
    w = jnp.where(jnp.isnan(w), 0.0, w)
    out = _gqa_values(w, v)
    out = out.reshape(b, h, s, d)
    # Dequantized KV runs the value matmul in f32; land back on the
    # activation dtype (bit-identical no-op on the unquantized path).
    return out.astype(out_dtype) if k_scale is not None else out


def gqa_attention_paged(
    q: jax.Array,                # (B, H, S, D)
    k_pages: jax.Array,          # (P, Hkv, page, D) physical page pool
    v_pages: jax.Array,
    table: jax.Array,            # (B, n_lp) int32 page table, 0 = unmapped
    *,
    buf_len: int,                # static contiguous view length
    causal: bool = True,
    q_offset=0,
    kv_len=None,
    window: int = 0,
    k_scale_pages: Optional[jax.Array] = None,
    v_scale_pages: Optional[jax.Array] = None,
    use_kernel: bool = False,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """``gqa_attention`` over a paged KV pool (DESIGN.md §12).

    Resolves the page table with the reference gather
    (``kernels.paged.gather_kv_pages``) into a contiguous
    ``(B, Hkv, buf_len, D)`` view and runs the identical attention math
    — bit-identical to contiguous by construction.  Unmapped table
    entries resolve to the zero page; zeros beyond ``kv_len`` are
    masked to exact ``-inf``, so an unmapped tail never contributes."""
    from repro.kernels.paged import gather_kv_pages
    k = gather_kv_pages(k_pages, table, buf_len)
    v = gather_kv_pages(v_pages, table, buf_len)
    ks = vs = None
    if k_scale_pages is not None:
        ks = gather_kv_pages(k_scale_pages, table, buf_len)
        vs = gather_kv_pages(v_scale_pages, table, buf_len)
    return gqa_attention(q, k, v, causal=causal, q_offset=q_offset,
                         kv_len=kv_len, window=window, k_scale=ks,
                         v_scale=vs, use_kernel=use_kernel,
                         interpret=interpret)


def chunked_attention(
    q: jax.Array,                # (B, H, S, D)
    k: jax.Array,                # (B, Hkv, T, D)
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int = 0,
    window: int = 0,
    kv_block: int = 1024,
) -> jax.Array:
    """Online-softmax attention streaming KV in blocks (flash-style).

    Memory is O(S * kv_block) instead of O(S * T).  Used for prefill at
    32k+; exactly matches ``attention`` numerically (up to fp assoc.).
    """
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    if t % kv_block:
        pad = kv_block - t % kv_block
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        t_pad = t + pad
    else:
        t_pad = t
    nblk = t_pad // kv_block
    qr = q.reshape(b, hkv, g, s, d)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    q_pos = q_offset + jnp.arange(s)

    k_blocks = k.reshape(b, hkv, nblk, kv_block, d).transpose(2, 0, 1, 3, 4)
    v_blocks = v.reshape(b, hkv, nblk, kv_block, d).transpose(2, 0, 1, 3, 4)

    def body(carry, xs):
        m, l, acc = carry
        blk_idx, kb, vb = xs
        scores = jnp.einsum("bhgsd,bhtd->bhgst", qr, kb,
                            preferred_element_type=jnp.float32) * scale
        k_pos = blk_idx * kv_block + jnp.arange(kv_block)
        mask = k_pos[None, :] < t  # drop pad
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        else:
            mask = jnp.broadcast_to(mask, (s, kv_block))
        if window:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
        m_blk = jnp.max(scores, axis=-1)
        m_new = jnp.maximum(m, m_blk)
        # Guard fully-masked-so-far rows (m_new could still be -inf).
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(scores - m_safe[..., None])
        p = jnp.where(jnp.isfinite(scores), p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgst,bhtd->bhgsd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, s), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, s, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (jnp.arange(nblk), k_blocks, v_blocks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, s, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_params(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu(params: dict, x: jax.Array) -> jax.Array:
    gate = jax.nn.silu(dense(x, params["w_gate"]))
    return dense(gate * dense(x, params["w_up"]), params["w_down"])


def gelu_mlp_params(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w_in": dense_init(k1, d_model, d_ff, dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": dense_init(k2, d_ff, d_model, dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(params: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ params["w_in"] + params["b_in"])
    return h @ params["w_out"] + params["b_out"]


# ---------------------------------------------------------------------------
# Attention block params (projections shared by all attention variants)
# ---------------------------------------------------------------------------


def attn_params(key, d_model: int, num_heads: int, kv_heads: int,
                head_dim: int, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d_model, num_heads * head_dim, dtype),
        "wk": dense_init(k2, d_model, kv_heads * head_dim, dtype),
        "wv": dense_init(k3, d_model, kv_heads * head_dim, dtype),
        "wo": dense_init(k4, num_heads * head_dim, d_model, dtype),
    }


def project_qkv(params: dict, x: jax.Array, num_heads: int, kv_heads: int,
                head_dim: int):
    b, s, _ = x.shape
    q = dense(x, params["wq"]).reshape(b, s, num_heads, head_dim).transpose(0, 2, 1, 3)
    k = dense(x, params["wk"]).reshape(b, s, kv_heads, head_dim).transpose(0, 2, 1, 3)
    v = dense(x, params["wv"]).reshape(b, s, kv_heads, head_dim).transpose(0, 2, 1, 3)
    return q, k, v


def project_out(params: dict, attn_out: jax.Array) -> jax.Array:
    b, h, s, d = attn_out.shape
    return dense(attn_out.transpose(0, 2, 1, 3).reshape(b, s, h * d),
                 params["wo"])
