"""Family registry: uniform model API dispatch.

Every family module exports:
  init_params(key, cfg), forward(params, cfg, batch, *, remat=...),
  init_cache(cfg, batch, max_len), prefill(params, cfg, batch, cache),
  decode_step(params, cfg, tokens, cache)
"""

from __future__ import annotations

from types import ModuleType

from repro.models.config import ModelConfig


def family_module(cfg: ModelConfig) -> ModuleType:
    from repro.models import encdec, mamba2, moe, rglru, transformer, vlm
    return {
        "dense": transformer,
        "moe": moe,
        "ssm": mamba2,
        "hybrid": rglru,
        "encdec": encdec,
        "vlm": vlm,
    }[cfg.family]


def init_params(key, cfg: ModelConfig):
    return family_module(cfg).init_params(key, cfg)


def forward(params, cfg: ModelConfig, batch, **kw):
    return family_module(cfg).forward(params, cfg, batch, **kw)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return family_module(cfg).init_cache(cfg, batch, max_len)


def prefill(params, cfg: ModelConfig, batch, cache):
    return family_module(cfg).prefill(params, cfg, batch, cache)


def decode_step(params, cfg: ModelConfig, tokens, cache):
    return family_module(cfg).decode_step(params, cfg, tokens, cache)


def param_count(params) -> int:
    import jax
    return sum(p.size for p in jax.tree_util.tree_leaves(params))
