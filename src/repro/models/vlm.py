"""Llama-3.2-Vision-style VLM backbone: a llama-family text decoder with
gated cross-attention layers interleaved every ``cross_attn_period``
self-attention layers (11B: 40 layers, 8 cross-attn).

The vision encoder + projector is a STUB per the assignment:
``batch["images"]`` carries precomputed patch embeddings
(B, num_image_tokens, d_model).  Cross-attention K/V over the image
tokens are computed once at prefill and reused at every decode step.

Scan layout: ``num_layers // period`` units of
(period-1 self-attn blocks, 1 cross-attn block), stacked and scanned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.stack import scan_blocks, stack_init


def layout(cfg: ModelConfig):
    period = cfg.cross_attn_period
    assert cfg.num_layers % period == 0, "vlm: num_layers % period != 0"
    return cfg.num_layers // period, period - 1  # (n_units, self per unit)


def _cross_block_init(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    dt = cfg.activation_dtype
    hd = cfg.resolved_head_dim
    return {
        "norm": L.rmsnorm_params(cfg.d_model, dt),
        "attn": L.attn_params(k1, cfg.d_model, cfg.num_heads, cfg.kv_heads,
                              hd, dt),
        "gate_attn": jnp.zeros((), jnp.float32),
        "gate_mlp": jnp.zeros((), jnp.float32),
        "mlp_norm": L.rmsnorm_params(cfg.d_model, dt),
        "mlp": L.swiglu_params(k2, cfg.d_model, cfg.d_ff, dt),
    }


def _unit_init(key, cfg: ModelConfig) -> dict:
    n_units, n_self = layout(cfg)
    keys = jax.random.split(key, n_self + 1)
    selfs = jax.vmap(lambda k: T._block_init(k, cfg))(keys[:-1])
    return {"self": selfs, "cross": _cross_block_init(keys[-1], cfg)}


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    n_units, _ = layout(cfg)
    k_embed, k_units, k_head = jax.random.split(key, 3)
    dt = cfg.activation_dtype
    return {
        "embed": L.embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dt),
        "units": stack_init(k_units, n_units, lambda k: _unit_init(k, cfg)),
        "final_norm": L.rmsnorm_params(cfg.d_model, dt),
        "lm_head": L.dense_init(k_head, cfg.d_model, cfg.padded_vocab, dt),
    }


def _cross_apply(p, cfg: ModelConfig, x, images=None, kv=None):
    """Gated cross-attention block.  Pass either raw image embeddings
    (computes K/V) or precomputed ``kv`` from the cache."""
    hd = cfg.resolved_head_dim
    xn = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    b, s, _ = x.shape
    q = (xn @ p["attn"]["wq"]).reshape(b, s, cfg.num_heads, hd).transpose(0, 2, 1, 3)
    if kv is None:
        k = (images @ p["attn"]["wk"]).reshape(
            b, -1, cfg.kv_heads, hd).transpose(0, 2, 1, 3)
        v = (images @ p["attn"]["wv"]).reshape(
            b, -1, cfg.kv_heads, hd).transpose(0, 2, 1, 3)
    else:
        k, v = kv
    out = L.attention(q, k, v, causal=False)
    g_attn = jnp.tanh(p["gate_attn"]).astype(x.dtype)
    g_mlp = jnp.tanh(p["gate_mlp"]).astype(x.dtype)
    x = x + g_attn * L.project_out(p["attn"], out)
    x = x + g_mlp * L.swiglu(
        p["mlp"], L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps))
    return x, (k, v)


def _unit_train(params_u, carry, _cache, cfg: ModelConfig, chunked):
    x, positions, images = carry
    from repro.sharding.context import constrain
    x = constrain(x, "layer_carry")
    n_self = jax.tree_util.tree_leaves(params_u["self"])[0].shape[0]
    for i in range(n_self):
        p_i = jax.tree.map(lambda a: a[i], params_u["self"])
        (x, positions), _ = T._block_train(p_i, (x, positions), None, cfg,
                                           chunked)
    x, _ = _cross_apply(params_u["cross"], cfg, x, images=images)
    return (x, positions, images), None


def forward(params: dict, cfg: ModelConfig, batch: dict, *,
            remat: bool = True, return_hidden: bool = False) -> jax.Array:
    tokens, images = batch["tokens"], batch["images"]
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    fn = functools.partial(_unit_train, cfg=cfg, chunked=s > 2048)
    (x, _, _), _ = scan_blocks(params["units"], (x, positions, images), fn,
                               remat=remat)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x
    return x @ params["lm_head"]


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    n_units, n_self = layout(cfg)
    hd = cfg.resolved_head_dim
    dt = cfg.activation_dtype
    t = T.cache_len(cfg, max_len)
    n_img = cfg.num_image_tokens
    return {
        "k": jnp.zeros((n_units, n_self, batch, cfg.kv_heads, t, hd), dt),
        "v": jnp.zeros((n_units, n_self, batch, cfg.kv_heads, t, hd), dt),
        "ck": jnp.zeros((n_units, batch, cfg.kv_heads, n_img, hd), dt),
        "cv": jnp.zeros((n_units, batch, cfg.kv_heads, n_img, hd), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def _unit_prefill(params_u, carry, cache_u, cfg: ModelConfig, chunked):
    x, positions, images = carry
    from repro.sharding.context import constrain
    x = constrain(x, "layer_carry")
    n_self = jax.tree_util.tree_leaves(params_u["self"])[0].shape[0]
    new_k, new_v = [], []
    for i in range(n_self):
        p_i = jax.tree.map(lambda a: a[i], params_u["self"])
        c_i = {"k": cache_u["k"][i], "v": cache_u["v"][i]}
        (x, positions), nc = T._block_prefill(p_i, (x, positions), c_i, cfg,
                                              chunked)
        new_k.append(nc["k"])
        new_v.append(nc["v"])
    x, (ck, cv) = _cross_apply(params_u["cross"], cfg, x, images=images)
    return (x, positions, images), {
        "k": jnp.stack(new_k), "v": jnp.stack(new_v), "ck": ck, "cv": cv}


def prefill(params: dict, cfg: ModelConfig, batch: dict, cache: dict):
    tokens, images = batch["tokens"], batch["images"]
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    fn = functools.partial(_unit_prefill, cfg=cfg, chunked=s > 2048)
    layer_cache = {"k": cache["k"], "v": cache["v"],
                   "ck": cache["ck"], "cv": cache["cv"]}
    (x, _, _), new_cache = scan_blocks(params["units"],
                                       (x, positions, images), fn,
                                       cache=layer_cache)
    x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = (x @ params["lm_head"])[:, 0]
    return logits, {**new_cache, "pos": jnp.asarray(s, jnp.int32)}


def _unit_decode(params_u, carry, cache_u, cfg: ModelConfig):
    x, pos = carry
    from repro.sharding.context import constrain
    x = constrain(x, "layer_carry")
    n_self = jax.tree_util.tree_leaves(params_u["self"])[0].shape[0]
    new_k, new_v = [], []
    for i in range(n_self):
        p_i = jax.tree.map(lambda a: a[i], params_u["self"])
        c_i = {"k": cache_u["k"][i], "v": cache_u["v"][i]}
        (x, pos), nc = T._block_decode(p_i, (x, pos), c_i, cfg)
        new_k.append(nc["k"])
        new_v.append(nc["v"])
    x, _ = _cross_apply(params_u["cross"], cfg, x,
                        kv=(cache_u["ck"], cache_u["cv"]))
    return (x, pos), {"k": jnp.stack(new_k), "v": jnp.stack(new_v),
                      "ck": cache_u["ck"], "cv": cache_u["cv"]}


def decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array, cache: dict):
    x = params["embed"][tokens]
    pos = cache["pos"]
    fn = functools.partial(_unit_decode, cfg=cfg)
    layer_cache = {"k": cache["k"], "v": cache["v"],
                   "ck": cache["ck"], "cv": cache["cv"]}
    (x, _), new_cache = scan_blocks(params["units"], (x, pos), fn,
                                    cache=layer_cache)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = (x @ params["lm_head"])[:, 0]
    return logits, {**new_cache, "pos": pos + 1}
