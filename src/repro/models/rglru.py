"""RecurrentGemma / Griffin hybrid (arXiv:2402.19427): residual blocks
cycle (recurrent, recurrent, local-attention); recurrent blocks use the
RG-LRU diagonal gated linear recurrence + short temporal conv; local
attention is MQA with a bounded window — so decode state is O(window),
qualifying this arch for long_500k.

Pattern handling: 26 layers = 8 scanned units of (rec, rec, attn) + 2
trailing recurrent blocks (see DESIGN.md).  Each temporal block is
followed by its own MLP sub-block (Griffin structure).

RG-LRU (per channel, diagonal):
  r_t = sigmoid(W_a x_t); i_t = sigmoid(W_x x_t)
  log a_t = -c * softplus(Λ) * r_t          (c = 8)
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t ⊙ x_t)
Implemented with an associative scan for full sequences (diagonal state ==
input width, so materialization is O(S * width)) and a one-step update for
decode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.stack import scan_blocks, stack_init

LRU_C = 8.0
CONV_WIDTH = 4


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def _lru_width(cfg: ModelConfig) -> int:
    return cfg.lru_width or cfg.d_model


def _rec_block_init(key, cfg: ModelConfig) -> dict:
    dt = cfg.activation_dtype
    w = _lru_width(cfg)
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "norm": L.rmsnorm_params(cfg.d_model, dt),
        "w_x": L.dense_init(k1, cfg.d_model, w, dt),       # recurrence branch
        "w_gate": L.dense_init(k2, cfg.d_model, w, dt),    # GeLU gate branch
        "conv_w": (jax.random.normal(k3, (CONV_WIDTH, w), jnp.float32)
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        "lru_wa": L.dense_init(k4, w, w, dt),
        "lru_wx": L.dense_init(k5, w, w, dt),
        "lru_lambda": jnp.full((w,), 1.0, jnp.float32),
        "w_out": L.dense_init(k6, w, cfg.d_model, dt),
        "mlp_norm": L.rmsnorm_params(cfg.d_model, dt),
        "mlp": L.swiglu_params(jax.random.fold_in(key, 7), cfg.d_model,
                               cfg.d_ff, dt),
    }


def _attn_block_init(key, cfg: ModelConfig) -> dict:
    dt = cfg.activation_dtype
    k1, k2 = jax.random.split(key)
    hd = cfg.resolved_head_dim
    return {
        "norm": L.rmsnorm_params(cfg.d_model, dt),
        "attn": L.attn_params(k1, cfg.d_model, cfg.num_heads, cfg.kv_heads,
                              hd, dt),
        "mlp_norm": L.rmsnorm_params(cfg.d_model, dt),
        "mlp": L.swiglu_params(k2, cfg.d_model, cfg.d_ff, dt),
    }


def _unit_init(key, cfg: ModelConfig) -> dict:
    """One scan unit: `pattern_rec` recurrent blocks + 1 attention block."""
    keys = jax.random.split(key, cfg.pattern_rec + 1)
    recs = jax.vmap(lambda k: _rec_block_init(k, cfg))(keys[:-1])
    return {"rec": recs, "attn": _attn_block_init(keys[-1], cfg)}


def layout(cfg: ModelConfig):
    """Return (n_units, n_extra_rec) covering cfg.num_layers blocks."""
    unit = cfg.pattern_rec + 1
    n_units = cfg.num_layers // unit
    extra = cfg.num_layers - n_units * unit  # trailing recurrent blocks
    return n_units, extra


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    n_units, extra = layout(cfg)
    k_embed, k_units, k_extra, k_head = jax.random.split(key, 4)
    dt = cfg.activation_dtype
    params = {
        "embed": L.embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dt),
        "units": stack_init(k_units, n_units, lambda k: _unit_init(k, cfg)),
        "final_norm": L.rmsnorm_params(cfg.d_model, dt),
        "lm_head": L.dense_init(k_head, cfg.d_model, cfg.padded_vocab, dt),
    }
    if extra:
        params["extra_rec"] = stack_init(
            k_extra, extra, lambda k: _rec_block_init(k, cfg))
    return params


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


def _lru_gates(p, x):
    """x: (..., W) branch input -> (log_a (f32), gated input (f32))."""
    r = jax.nn.sigmoid((x @ p["lru_wa"]).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ p["lru_wx"]).astype(jnp.float32))
    log_a = -LRU_C * jax.nn.softplus(p["lru_lambda"]) * r
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    u = beta * i * x.astype(jnp.float32)
    return log_a, u


def rg_lru_scan(p, x, h0=None):
    """Full-sequence RG-LRU.  x: (B, S, W) -> (y (B,S,W), h_final (B,W))."""
    log_a, u = _lru_gates(p, x)

    def combine(c1, c2):
        a1, u1 = c1
        a2, u2 = c2
        return a1 + a2, u1 * jnp.exp(a2) + u2

    a_acc, h = jax.lax.associative_scan(combine, (log_a, u), axis=1)
    if h0 is not None:
        h = h + jnp.exp(a_acc) * h0[:, None, :].astype(jnp.float32)
    return h.astype(x.dtype), h[:, -1]


def rg_lru_step(p, x, h_prev):
    """x: (B, 1, W); h_prev: (B, W) f32."""
    log_a, u = _lru_gates(p, x)
    h = jnp.exp(log_a[:, 0]) * h_prev + u[:, 0]
    return h.astype(x.dtype)[:, None, :], h


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _rec_apply(p, cfg, x, cache=None, decode=False):
    """Recurrent block + MLP.  cache: {"conv": (B,CW-1,W), "h": (B,W)}."""
    res = x
    xn = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    branch = xn @ p["w_x"]
    gate = jax.nn.gelu(xn @ p["w_gate"])
    from repro.models.mamba2 import causal_conv
    conv_state = cache["conv"] if cache is not None else None
    branch, new_conv = causal_conv(p["conv_w"], p["conv_b"], branch,
                                   state=conv_state if decode else None)
    if decode:
        y, h_new = rg_lru_step(p, branch, cache["h"])
    else:
        h0 = cache["h"] if cache is not None else None
        y, h_new = rg_lru_scan(p, branch, h0=None)
    x = res + (y * gate) @ p["w_out"]
    x = x + L.swiglu(p["mlp"], L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps))
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "h": h_new.astype(jnp.float32)}
    return x, new_cache


def _attn_apply(p, cfg, x, positions, cache=None, pos=None):
    """Local-attention block + MLP.  Full-seq when cache-less or prefill;
    single-step ring-buffer decode when ``pos`` is given."""
    hd = cfg.resolved_head_dim
    res = x
    xn = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    q, k, v = L.project_qkv(p["attn"], xn, cfg.num_heads, cfg.kv_heads, hd)
    if pos is None:  # full sequence
        q = L.apply_rope(q, positions[:, None, :], cfg.rope_theta)
        k = L.apply_rope(k, positions[:, None, :], cfg.rope_theta)
        s = x.shape[1]
        if s > 2048:
            out = L.chunked_attention(q, k, v, causal=True,
                                      window=cfg.local_window)
        else:
            out = L.attention(q, k, v, causal=True, window=cfg.local_window)
        new_cache = None
        if cache is not None:
            t_cache = cache["k"].shape[2]
            if s >= t_cache:
                tail = jax.lax.dynamic_slice_in_dim(k, s - t_cache, t_cache, 2)
                tail_v = jax.lax.dynamic_slice_in_dim(v, s - t_cache, t_cache, 2)
                shift = s % t_cache
                idx = (jnp.arange(t_cache) - shift) % t_cache
                new_k = tail[:, :, idx] if shift else tail
                new_v = tail_v[:, :, idx] if shift else tail_v
            else:
                new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 2)
                new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 2)
            new_cache = {"k": new_k, "v": new_v}
    else:  # decode
        posb = jnp.broadcast_to(pos[None, None], (x.shape[0], 1, 1))
        q = L.apply_rope(q, posb, cfg.rope_theta)
        k = L.apply_rope(k, posb, cfg.rope_theta)
        t_cache = cache["k"].shape[2]
        slot = pos % t_cache
        new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, 2)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, 2)
        kv_len = jnp.minimum(pos + 1, t_cache)
        out = L.attention(q, new_k, new_v, causal=False, kv_len=kv_len)
        new_cache = {"k": new_k, "v": new_v}
    x = res + L.project_out(p["attn"], out)
    x = x + L.swiglu(p["mlp"], L.rmsnorm(p["mlp_norm"], x, cfg.norm_eps))
    return x, new_cache


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    n_units, extra = layout(cfg)
    w = _lru_width(cfg)
    t = min(max_len, cfg.local_window)
    hd = cfg.resolved_head_dim
    dt = cfg.activation_dtype

    def rec_cache(n):
        return {"conv": jnp.zeros((n, cfg.pattern_rec, batch,
                                   CONV_WIDTH - 1, w), dt)
                if n else None,
                "h": jnp.zeros((n, cfg.pattern_rec, batch, w), jnp.float32)
                if n else None}

    cache = {
        "units": {
            "rec": {"conv": jnp.zeros((n_units, cfg.pattern_rec, batch,
                                       CONV_WIDTH - 1, w), dt),
                    "h": jnp.zeros((n_units, cfg.pattern_rec, batch, w),
                                   jnp.float32)},
            "attn": {"k": jnp.zeros((n_units, batch, cfg.kv_heads, t, hd), dt),
                     "v": jnp.zeros((n_units, batch, cfg.kv_heads, t, hd), dt)},
        },
        "pos": jnp.zeros((), jnp.int32),
    }
    if extra:
        cache["extra_rec"] = {
            "conv": jnp.zeros((extra, batch, CONV_WIDTH - 1, w), dt),
            "h": jnp.zeros((extra, batch, w), jnp.float32),
        }
    return cache


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def _unit_apply(params_u, carry, cache_u, cfg: ModelConfig, decode=False):
    x, positions, pos = carry
    from repro.sharding.context import constrain
    x = constrain(x, "layer_carry")
    new_rec_conv, new_rec_h = [], []
    for i in range(cfg.pattern_rec):
        p_i = jax.tree.map(lambda a: a[i], params_u["rec"])
        c_i = None
        if cache_u is not None:
            c_i = {"conv": cache_u["rec"]["conv"][i],
                   "h": cache_u["rec"]["h"][i]}
        x, nc = _rec_apply(p_i, cfg, x, cache=c_i, decode=decode)
        if nc is not None:
            new_rec_conv.append(nc["conv"])
            new_rec_h.append(nc["h"])
    attn_cache = cache_u["attn"] if cache_u is not None else None
    x, new_attn = _attn_apply(params_u["attn"], cfg, x, positions,
                              cache=attn_cache, pos=pos if decode else None)
    new_cache = None
    if cache_u is not None:
        new_cache = {"rec": {"conv": jnp.stack(new_rec_conv),
                             "h": jnp.stack(new_rec_h)},
                     "attn": new_attn}
    return (x, positions, pos), new_cache


def _run(params, cfg: ModelConfig, x, positions, cache=None, pos=None,
         remat=False):
    decode = pos is not None
    fn = functools.partial(_unit_apply, cfg=cfg, decode=decode)
    unit_cache = cache["units"] if cache is not None else None
    (x, _, _), new_units = scan_blocks(params["units"], (x, positions, pos),
                                       fn, cache=unit_cache, remat=remat)
    new_extra = None
    if "extra_rec" in params:
        n_extra = jax.tree_util.tree_leaves(params["extra_rec"])[0].shape[0]
        convs, hs = [], []
        for i in range(n_extra):
            p_i = jax.tree.map(lambda a: a[i], params["extra_rec"])
            c_i = None
            if cache is not None:
                c_i = {"conv": cache["extra_rec"]["conv"][i],
                       "h": cache["extra_rec"]["h"][i]}
            x, nc = _rec_apply(p_i, cfg, x, cache=c_i, decode=decode)
            if nc is not None:
                convs.append(nc["conv"])
                hs.append(nc["h"])
        if convs:
            new_extra = {"conv": jnp.stack(convs), "h": jnp.stack(hs)}
    return x, new_units, new_extra


def forward(params: dict, cfg: ModelConfig, batch: dict, *,
            remat: bool = True, return_hidden: bool = False) -> jax.Array:
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, _, _ = _run(params, cfg, x, positions, remat=remat)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x
    return x @ params["lm_head"]


def prefill(params: dict, cfg: ModelConfig, batch: dict, cache: dict):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, new_units, new_extra = _run(params, cfg, x, positions, cache=cache)
    x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = (x @ params["lm_head"])[:, 0]
    new_cache = {"units": new_units, "pos": jnp.asarray(s, jnp.int32)}
    if new_extra is not None:
        new_cache["extra_rec"] = new_extra
    return logits, new_cache


def decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array, cache: dict):
    x = params["embed"][tokens]
    pos = cache["pos"]
    positions = None
    x, new_units, new_extra = _run(params, cfg, x, positions, cache=cache,
                                   pos=pos)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = (x @ params["lm_head"])[:, 0]
    new_cache = {"units": new_units, "pos": pos + 1}
    if new_extra is not None:
        new_cache["extra_rec"] = new_extra
    return logits, new_cache
