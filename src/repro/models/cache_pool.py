"""Slot-based KV-cache arena for multi-request cached serving.

One pool holds the persistent decode state for up to ``num_slots`` live
requests at once, for every model that participates in a serving step
(speculative decoding needs two: target and drafter).  Each request owns
one *slot* = ``rows_per_slot`` consecutive batch rows of a shared
``(layers, num_slots * rows_per_slot, kv_heads, buf_len, head_dim)``
cache — for spec-dec the rows are the K draft lanes.  All live requests
then advance in ONE ``decode_step_slots`` / ``verify_step_slots`` call
over the whole arena; free slots ride along as dead rows (their garbage
is never attended by other rows and is fully overwritten at the next
admission's prefill scatter).

Lifecycle contract (DESIGN.md §7):

  * ``alloc``/``release`` at request admission/completion; allocation is
    lowest-free-slot first, so a given request trace maps to slots
    deterministically;
  * per-slot positions are tracked HOST-side (``pool.pos``) — reading a
    position never costs a device sync, and the model-call API takes
    positions as an argument instead of carrying them in the cache dict;
  * per-slot rollback is row replication: after block verification the
    surviving draft row's cache is broadcast across the slot's rows (one
    arena-wide gather for all slots at once, ``rollback_rows``);
  * ``ensure_buf`` grows every arena to a longer buffer (zero-padded on
    the time axis) when a larger request is admitted; buffer length only
    ever grows, mirroring the scheduler's monotone buffer policy.

int8 arenas (DESIGN.md §11): ``quant=True`` stores each arena as four
leaves — int8 ``k``/``v`` plus f32 per-KV-vector scales ``k_s``/``v_s``
with a trailing singleton axis, so every arena op here (row gather /
scatter on axis 1, time growth on axis 3) applies uniformly to all
leaves.  The slots model calls quantize on write and dequantize inside
the attention reads; ``write_prefill`` quantizes dense prefill caches on
install.

Positions live in TWO places (DESIGN.md §8): the host mirror
(``pool.pos``) is authoritative for admission/allocation and sizing
decisions, and a lazily materialized device copy (``pos_device()``)
feeds the fused round program, which advances positions in-program and
hands back the updated array (``adopt_round_device``).  Host-side
lifecycle writes (alloc/release/prefill) update the device copy
PER SLOT (``_touch_pos`` — one ``.at[slot].set`` element write), so
admitting or releasing one request never re-uploads every live slot's
positions; the fused round refreshes the host mirror for the slots it
advanced from its packed result (``refresh_pos_host``), so the two
views never drift.
"""

from __future__ import annotations

import functools
import heapq
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import paged as P
from repro.models.config import ModelConfig
from repro.models.registry import init_cache


@jax.jit
def _gather_rows(leaf, idx):
    return jnp.take(leaf, idx, axis=1)


@functools.partial(jax.jit, static_argnames=("r0",))
def _scatter_rows(leaf, rows, r0: int):
    return jax.lax.dynamic_update_slice_in_dim(leaf, rows, r0, axis=1)


@jax.jit
def _grow_time(new_leaf, old_leaf):
    t_old = old_leaf.shape[3]
    return jax.lax.dynamic_update_slice_in_dim(
        new_leaf, old_leaf, 0, axis=3) if t_old else new_leaf


class CachePool:
    """Multi-model slot arena; see module docstring for the contract."""

    def __init__(self, cfgs: Dict[str, ModelConfig], num_slots: int,
                 rows_per_slot: int, buf_len: int, quant: bool = False):
        assert num_slots >= 1 and rows_per_slot >= 1
        for cfg in cfgs.values():
            assert not cfg.sliding_window, \
                "CachePool: non-ring (full-attention) caches only"
        self.cfgs = dict(cfgs)
        self.num_slots = num_slots
        self.rows_per_slot = rows_per_slot
        self.buf_len = buf_len
        self.quant = quant
        self.caches = {name: self._init_arena(cfg, buf_len)
                       for name, cfg in self.cfgs.items()}
        # Host-side per-slot decode position (== tokens whose KV is live).
        self.pos = np.zeros(num_slots, np.int64)
        # Device copy of ``pos`` for the fused round program; rebuilt
        # lazily after any host-side position write (DESIGN.md §8).
        self._pos_dev = None
        self._free = list(range(num_slots))

    def _init_arena(self, cfg: ModelConfig, buf_len: int) -> dict:
        c = init_cache(cfg, self.num_slots * self.rows_per_slot, buf_len)
        arena = {"k": c["k"], "v": c["v"]}   # positions live host-side
        if self.quant:
            # int8 leaves + per-KV-vector f32 scales (trailing-1 axis).
            sshape = c["k"].shape[:-1] + (1,)
            arena = {"k": jnp.zeros(c["k"].shape, jnp.int8),
                     "v": jnp.zeros(c["v"].shape, jnp.int8),
                     "k_s": jnp.zeros(sshape, jnp.float32),
                     "v_s": jnp.zeros(sshape, jnp.float32)}
        return arena

    # -- slot lifecycle ----------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError(
                f"CachePool: all {self.num_slots} slots in use")
        slot = min(self._free)
        self._free.remove(slot)
        self.pos[slot] = 0
        self._touch_pos(slot)
        return slot

    def release(self, slot: int) -> None:
        assert 0 <= slot < self.num_slots and slot not in self._free
        self.pos[slot] = 0
        self._touch_pos(slot)
        self._free.append(slot)

    def set_pos(self, slot: int, pos: int) -> None:
        """Record a slot's new decode position (host mirror + per-slot
        device touch) — the host-driven round's position write."""
        self.pos[slot] = int(pos)
        self._touch_pos(slot)

    def rows_of(self, slot: int) -> np.ndarray:
        r = self.rows_per_slot
        return np.arange(slot * r, (slot + 1) * r)

    # -- buffer growth -----------------------------------------------------
    def ensure_buf(self, buf_len: int) -> None:
        """Grow every arena's time axis to at least ``buf_len``.  Existing
        KV content (all live positions) is preserved; new tail is zero."""
        if buf_len <= self.buf_len:
            return
        for name, cfg in self.cfgs.items():
            fresh = self._init_arena(cfg, buf_len)
            old = self.caches[name]
            self.caches[name] = {kk: _grow_time(fresh[kk], old[kk])
                                 for kk in fresh}
        self.buf_len = buf_len

    # -- cache content ops -------------------------------------------------
    def write_prefill(self, name: str, slot: int, cache: dict,
                      pos: int) -> None:
        """Install a freshly prefilled ``(layers, rows_per_slot, ...)``
        cache into ``slot``'s rows of arena ``name``; ``pos`` is the
        number of prefilled tokens.  The prefill cache must have been
        built at the pool's current ``buf_len``.  Quant pools accept a
        dense {k, v} prefill cache and quantize it on install."""
        arena = self.caches[name]
        assert cache["k"].shape[3] == self.buf_len, \
            "prefill cache buffer != pool buffer"
        if self.quant and "k_s" not in cache:
            from repro.serving.quant import quantize_kv
            kq, ks = quantize_kv(cache["k"])
            vq, vs = quantize_kv(cache["v"])
            cache = {"k": kq, "v": vq, "k_s": ks, "v_s": vs}
        r0 = slot * self.rows_per_slot
        self.caches[name] = {kk: _scatter_rows(arena[kk], cache[kk], r0=r0)
                             for kk in arena}
        self.pos[slot] = pos
        self._touch_pos(slot)

    def update(self, name: str, cache: dict) -> None:
        """Adopt the arena returned by a slots model call."""
        self.caches[name] = {kk: cache[kk] for kk in self.caches[name]}

    def rollback_rows(self, row_src: np.ndarray) -> None:
        """Arena-wide row replication: row i of every cache becomes row
        ``row_src[i]``.  Callers build ``row_src`` so each rolled-back
        slot's rows all point at its surviving row and every other row
        points at itself."""
        assert row_src.shape == (self.num_slots * self.rows_per_slot,)
        idx = jnp.asarray(row_src, jnp.int32)
        for name, arena in self.caches.items():
            self.caches[name] = {kk: _gather_rows(arena[kk], idx)
                                 for kk in arena}

    # -- fused-round device state (DESIGN.md §8) ---------------------------
    def _touch_pos(self, slot: int) -> None:
        """Per-slot device-position update after a host lifecycle write:
        one ``.at[slot].set`` element write instead of invalidating (and
        re-uploading) the whole position array.  No-op while the device
        copy has never been materialized."""
        if self._pos_dev is not None:
            self._pos_dev = self._pos_dev.at[slot].set(
                jnp.int32(int(self.pos[slot])))

    def pos_device(self) -> jax.Array:
        """(num_slots,) i32 device positions for the fused round program.
        Materialized from the host mirror once; afterwards the array
        handed back by the previous round (plus per-slot lifecycle
        touches) is reused, so the steady-state round uploads nothing."""
        if self._pos_dev is None:
            self._pos_dev = jnp.asarray(self.pos, jnp.int32)
        return self._pos_dev

    def adopt_round_device(self, caches: Dict[str, dict],
                           pos_dev: jax.Array) -> None:
        """Adopt a fused round program's DEVICE outputs: the per-model
        {k, v} arenas (the donated input buffers are dead — callers must
        never touch them again) and the advanced device positions.
        Deliberately host-async: callers may dispatch more device work
        (admission prefills, §9) against the adopted arrays before the
        round's packed result is fetched; the host mirror stays stale
        for the advanced slots until ``refresh_pos_host``."""
        assert set(caches) == set(self.caches)
        for name, c in caches.items():
            self.caches[name] = {kk: c[kk] for kk in self.caches[name]}
        self._pos_dev = pos_dev

    def adopt_pos_device(self, pos_dev: jax.Array) -> None:
        """Adopt ONLY a fused round's advanced device positions.  Used
        when the round's KV lives in a caller-held contiguous view
        rather than in pool storage (the paged kv_fused path, §12):
        positions still flow through the pool's device mirror, storage
        syncs separately at view-commit events."""
        self._pos_dev = pos_dev

    def refresh_pos_host(self, pos_host: np.ndarray, slots) -> None:
        """Refresh the host position mirror for ``slots`` from a fused
        round's packed result.  Only the slots the round advanced are
        written — slots admitted while the round ran already hold their
        post-prefill positions host-side, and the round's packed ``pos``
        (snapshotted at dispatch) would clobber them."""
        for s in slots:
            self.pos[s] = int(pos_host[s])

    def row_positions(self, default: int = 0) -> np.ndarray:
        """(num_slots * rows_per_slot,) per-row positions for the slots
        model calls; free slots get ``default``."""
        per_slot = self.pos.copy()
        for s in self._free:
            per_slot[s] = default
        return np.repeat(per_slot, self.rows_per_slot).astype(np.int32)

    # -- fault recovery (DESIGN.md §13) ------------------------------------
    def drop_device_mirrors(self) -> None:
        """Invalidate the lazily-materialized device mirrors after a
        guarded fault discarded a round mid-flight.  The host views
        (``pos``, and the page table in the paged pool) are
        authoritative and re-upload on next use, so device state that
        adopted an aborted round's in-flight outputs can never leak
        into the replay."""
        self._pos_dev = None

    def scrub(self) -> None:
        """Zero every arena — the NaN-poisoning recovery (DESIGN.md
        §13).  Finite garbage in dead arena regions is masked out of
        every read (the §7/§12 dead-row argument), but NaN/Inf garbage
        is NOT: a masked attention weight of 0.0 against a NaN value
        still contributes ``0 * NaN = NaN`` to the output sum, so
        possibly-poisoned storage must be rebuilt, not reused.  Callers
        displace every session first — all slots must be free."""
        assert len(self._free) == self.num_slots, \
            "scrub with occupied slots; displace sessions first"
        self.caches = {name: self._init_arena(cfg, self.buf_len)
                       for name, cfg in self.cfgs.items()}
        self.pos[:] = 0
        self._pos_dev = None


@jax.jit
def _grow_pages_leaf(new_leaf, old_leaf):
    return jax.lax.dynamic_update_slice_in_dim(new_leaf, old_leaf, 0, axis=1)


class PagePoolExhausted(RuntimeError):
    """A fixed-budget paged pool ran out of physical pages.  The
    scheduler's v2 policy treats this as its eviction signal boundary —
    it reserves conservatively ahead of every round, so hitting this
    means the caller's accounting is wrong, not that eviction is due."""


class PagedCachePool(CachePool):
    """Paged slot arena (DESIGN.md §12): same lifecycle contract and
    model-facing semantics as ``CachePool``, but each model's KV lives
    in fixed-size physical pages ``(layers, num_pages + 1, kv_heads,
    page_size, head_dim)`` behind ONE page table ``(rows, n_lp)`` shared
    by every model (positions are shared, so all models' chains advance
    in lockstep; physical page index ``p`` names page ``p`` in every
    model's storage at once).  Physical page 0 is a permanent zero page
    and table entry 0 means unmapped — see models/paged.py for the
    gather/scatter semantics that make dead rows and reused (garbage)
    pages token-invisible.

    Differences from the contiguous pool:

      * ``ensure_buf`` is a table WIDENING (append unmapped columns) —
        no storage copy, no whole-pool zero-pad regrowth;
      * storage is reserved per slot as its chain grows (``reserve``;
        ``write_prefill`` reserves for the prompt, engines reserve
        ``pos + L + 1`` before each round), so a free slot holds zero
        pages and a fixed ``num_pages`` budget can oversubscribe slots
        (more queued requests than physical capacity) — exhausting a
        fixed budget raises ``PagePoolExhausted``; with ``num_pages=
        None`` the pool starts at full contiguous-equivalent capacity
        and doubles on demand;
      * model calls run the ``*_slots_paged`` entry points (pages +
        device table) instead of taking ``pool.caches`` — this class
        deliberately does NOT define ``caches``, so contiguous-only
        code paths fail loudly;
      * rollback replicates chain CONTENT page-by-page through the
        table (``models/paged.replicate_rows``) — rows keep their own
        physical pages.
    """

    def __init__(self, cfgs: Dict[str, ModelConfig], num_slots: int,
                 rows_per_slot: int, buf_len: int, quant: bool = False,
                 page_size: int = 64, num_pages: Optional[int] = None):
        assert num_slots >= 1 and rows_per_slot >= 1 and page_size >= 1
        for cfg in cfgs.values():
            assert not cfg.sliding_window, \
                "PagedCachePool: non-ring (full-attention) caches only"
        self.cfgs = dict(cfgs)
        self.num_slots = num_slots
        self.rows_per_slot = rows_per_slot
        self.buf_len = buf_len
        self.quant = quant
        self.page_size = page_size
        self.n_lp = P.n_logical_pages(buf_len, page_size)
        rows = num_slots * rows_per_slot
        self.fixed_budget = num_pages is not None
        self.num_pages = num_pages if self.fixed_budget \
            else rows * self.n_lp
        assert self.num_pages >= 1
        self.pages = {name: self._init_pages(cfg, self.num_pages)
                      for name, cfg in self.cfgs.items()}
        # Shared page table: host-authoritative, device mirror lazy
        # (same two-view discipline as positions, DESIGN.md §8).
        self.page_table = np.zeros((rows, self.n_lp), np.int32)
        self._pt_dev = None
        self._free_pages = list(range(1, self.num_pages + 1))
        heapq.heapify(self._free_pages)       # lowest-free-page first
        self._chain_len = np.zeros(num_slots, np.int64)
        self.pos = np.zeros(num_slots, np.int64)
        self._pos_dev = None
        self._free = list(range(num_slots))

    def _init_pages(self, cfg: ModelConfig, num_pages: int) -> dict:
        c = init_cache(cfg, 1, self.page_size)
        shape = (c["k"].shape[0], num_pages + 1) + c["k"].shape[2:]
        pages = {"k": jnp.zeros(shape, c["k"].dtype),
                 "v": jnp.zeros(shape, c["v"].dtype)}
        if self.quant:
            sshape = shape[:-1] + (1,)
            pages = {"k": jnp.zeros(shape, jnp.int8),
                     "v": jnp.zeros(shape, jnp.int8),
                     "k_s": jnp.zeros(sshape, jnp.float32),
                     "v_s": jnp.zeros(sshape, jnp.float32)}
        return pages

    # -- page allocation ---------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free_pages)

    def chain_pages(self, n_tokens: int) -> int:
        """Pages ONE row needs to cover ``n_tokens`` positions."""
        return P.n_logical_pages(max(int(n_tokens), 0), self.page_size)

    def held_pages(self, slot: int) -> int:
        """Physical pages currently owned by ``slot`` (all its rows)."""
        return int(self._chain_len[slot]) * self.rows_per_slot

    def reserve(self, slot: int, n_tokens: int) -> None:
        """Extend ``slot``'s chains (every row in lockstep) to cover
        ``n_tokens`` logical positions.  Never shrinks.  Raises
        ``PagePoolExhausted`` on a fixed budget (before mutating
        anything); auto-grow pools double their storage instead."""
        need_lp = self.chain_pages(n_tokens)
        assert need_lp <= self.n_lp, (
            f"reserve({n_tokens}) needs {need_lp} logical pages but the "
            f"table holds {self.n_lp}; grow buf_len first (ensure_buf)")
        have = int(self._chain_len[slot])
        if need_lp <= have:
            return
        want = (need_lp - have) * self.rows_per_slot
        if want > len(self._free_pages):
            if self.fixed_budget:
                raise PagePoolExhausted(
                    f"slot {slot} needs {want} pages, "
                    f"{len(self._free_pages)}/{self.num_pages} free")
            self._grow_pages(want - len(self._free_pages))
        r0 = slot * self.rows_per_slot
        for lp in range(have, need_lp):
            for r in range(r0, r0 + self.rows_per_slot):
                self.page_table[r, lp] = heapq.heappop(self._free_pages)
        self._chain_len[slot] = need_lp
        self._touch_table(slot)

    def _grow_pages(self, min_extra: int) -> None:
        """Auto-grow storage: at least double (amortized O(1) copies),
        at least ``min_extra`` new pages.  Page indices are stable, so
        the table is untouched."""
        new_total = max(self.num_pages * 2, self.num_pages + min_extra)
        for name, cfg in self.cfgs.items():
            fresh = self._init_pages(cfg, new_total)
            old = self.pages[name]
            self.pages[name] = {kk: _grow_pages_leaf(fresh[kk], old[kk])
                                for kk in fresh}
        self._free_pages.extend(range(self.num_pages + 1, new_total + 1))
        heapq.heapify(self._free_pages)
        self.num_pages = new_total

    def release(self, slot: int) -> None:
        """Free the slot AND its pages.  Clearing the slot's table rows
        is what keeps its dead rows harmless: their in-round garbage
        writes redirect through unmapped entries and DROP, so a freed
        page reallocated to another request can never be corrupted by
        the releasing slot riding along in a later round."""
        r0 = slot * self.rows_per_slot
        r1 = r0 + self.rows_per_slot
        for pg in self.page_table[r0:r1].reshape(-1):
            if pg > 0:
                heapq.heappush(self._free_pages, int(pg))
        self.page_table[r0:r1] = 0
        self._chain_len[slot] = 0
        self._touch_table(slot)
        super().release(slot)

    # -- suspend / resume (DESIGN.md §12): pages without a slot ------------
    def detach(self, slot: int) -> dict:
        """Suspend a slot's request: free the SLOT but keep its PAGES.
        Returns a handle owning the chains; ``attach`` later re-binds
        them to any free slot — a host table rewrite, no KV copy and no
        recompute — and ``release_handle`` forfeits them.  Detached
        pages are in neither the free heap (no other slot can claim
        them) nor the table (no round can write them): the bytes the
        handle owns are exactly the bytes the request left behind."""
        r0 = slot * self.rows_per_slot
        r1 = r0 + self.rows_per_slot
        handle = {"chains": self.page_table[r0:r1].copy(),
                  "chain_len": int(self._chain_len[slot]),
                  "pos": int(self.pos[slot])}
        self.page_table[r0:r1] = 0
        self._chain_len[slot] = 0
        self._touch_table(slot)
        super().release(slot)
        return handle

    def attach(self, slot: int, handle: dict) -> None:
        """Re-bind a detached handle's chains to ``slot``.  The table
        may have WIDENED since detach (``ensure_buf``); the extra
        columns stay unmapped, same as any short chain."""
        r0 = slot * self.rows_per_slot
        r1 = r0 + self.rows_per_slot
        chains = handle["chains"]
        assert chains.shape[0] == self.rows_per_slot
        assert chains.shape[1] <= self.n_lp
        assert not self.page_table[r0:r1].any()
        self.page_table[r0:r1, :chains.shape[1]] = chains
        self._chain_len[slot] = int(handle["chain_len"])
        self._touch_table(slot)
        self.set_pos(slot, int(handle["pos"]))

    def release_handle(self, handle: dict) -> None:
        """Forfeit a suspended request's pages (demotion to a hard
        eviction — re-admission goes back through re-prefill)."""
        for pg in handle["chains"].reshape(-1):
            if pg > 0:
                heapq.heappush(self._free_pages, int(pg))
        handle["chains"] = np.zeros_like(handle["chains"])
        handle["chain_len"] = 0

    # -- device table mirror -----------------------------------------------
    def _touch_table(self, slot: int) -> None:
        """Per-slot device-table update after a host-side chain change
        (reserve/release) — one row-range write, not a full re-upload."""
        if self._pt_dev is not None:
            r0 = slot * self.rows_per_slot
            r1 = r0 + self.rows_per_slot
            self._pt_dev = self._pt_dev.at[r0:r1].set(
                jnp.asarray(self.page_table[r0:r1]))

    def pt_device(self) -> jax.Array:
        """(rows, n_lp) i32 device page table for the paged model calls;
        lazily materialized from the host mirror, then maintained by
        per-slot touches."""
        if self._pt_dev is None:
            self._pt_dev = jnp.asarray(self.page_table)
        return self._pt_dev

    # -- buffer growth: table widening, NOT a storage copy -----------------
    def ensure_buf(self, buf_len: int) -> None:
        if buf_len <= self.buf_len:
            return
        new_lp = P.n_logical_pages(buf_len, self.page_size)
        if new_lp > self.n_lp:
            rows = self.num_slots * self.rows_per_slot
            pad = np.zeros((rows, new_lp - self.n_lp), np.int32)
            self.page_table = np.concatenate([self.page_table, pad], axis=1)
            self.n_lp = new_lp
            self._pt_dev = None        # shape changed; re-upload lazily
        self.buf_len = buf_len

    # -- cache content ops -------------------------------------------------
    def write_prefill(self, name: str, slot: int, cache: dict,
                      pos: int) -> None:
        assert cache["k"].shape[3] == self.buf_len, \
            "prefill cache buffer != pool buffer"
        if self.quant and "k_s" not in cache:
            from repro.serving.quant import quantize_kv
            kq, ks = quantize_kv(cache["k"])
            vq, vs = quantize_kv(cache["v"])
            cache = {"k": kq, "v": vq, "k_s": ks, "v_s": vs}
        self.reserve(slot, pos)
        r0 = slot * self.rows_per_slot
        tbl = jnp.asarray(self.page_table[r0:r0 + self.rows_per_slot])
        self.pages[name] = P.scatter_arena_jit(
            self.pages[name], tbl, {kk: cache[kk] for kk in self.pages[name]})
        self.pos[slot] = pos
        self._touch_pos(slot)

    def update(self, name: str, pages: dict) -> None:
        """Adopt the pages returned by a ``*_slots_paged`` model call."""
        self.pages[name] = {kk: pages[kk] for kk in self.pages[name]}

    def rollback_rows(self, row_src: np.ndarray) -> None:
        assert row_src.shape == (self.num_slots * self.rows_per_slot,)
        idx = jnp.asarray(row_src, jnp.int32)
        pt = self.pt_device()
        for name in self.pages:
            self.pages[name] = P.replicate_rows_jit(
                self.pages[name], pt, idx)

    def adopt_round_device(self, pages: Dict[str, dict],
                           pos_dev: jax.Array) -> None:
        """Adopt a paged fused round's DEVICE outputs (per-model page
        storage + advanced positions); same host-async contract as the
        contiguous pool's ``adopt_round_device``."""
        assert set(pages) == set(self.pages)
        for name, pg in pages.items():
            self.pages[name] = {kk: pg[kk] for kk in self.pages[name]}
        self._pos_dev = pos_dev

    def materialize(self, name: str) -> dict:
        """Gather one model's full contiguous arena view (tests and
        debugging only — the serving paths never materialize this)."""
        return P.gather_arena_jit(self.pages[name], self.pt_device(),
                                  buf_len=self.buf_len)

    # -- fault recovery (DESIGN.md §13) ------------------------------------
    def drop_device_mirrors(self) -> None:
        super().drop_device_mirrors()
        self._pt_dev = None

    def scrub(self) -> None:
        """Zero page storage (see ``CachePool.scrub``).  All slots must
        be free AND all pages returned — a suspend handle's detached
        pages are invisible to the pool, so callers strip outstanding
        handles first (their bytes may be poisoned too)."""
        assert len(self._free) == self.num_slots, \
            "scrub with occupied slots; displace sessions first"
        assert len(self._free_pages) == self.num_pages, \
            "scrub with pages still held; strip suspend handles first"
        assert not self.page_table.any()
        self.pages = {name: self._init_pages(cfg, self.num_pages)
                      for name, cfg in self.cfgs.items()}
        self.pos[:] = 0
        self._pos_dev = None
        self._pt_dev = None
