"""Slot-based KV-cache arena for multi-request cached serving.

One pool holds the persistent decode state for up to ``num_slots`` live
requests at once, for every model that participates in a serving step
(speculative decoding needs two: target and drafter).  Each request owns
one *slot* = ``rows_per_slot`` consecutive batch rows of a shared
``(layers, num_slots * rows_per_slot, kv_heads, buf_len, head_dim)``
cache — for spec-dec the rows are the K draft lanes.  All live requests
then advance in ONE ``decode_step_slots`` / ``verify_step_slots`` call
over the whole arena; free slots ride along as dead rows (their garbage
is never attended by other rows and is fully overwritten at the next
admission's prefill scatter).

Lifecycle contract (DESIGN.md §7):

  * ``alloc``/``release`` at request admission/completion; allocation is
    lowest-free-slot first, so a given request trace maps to slots
    deterministically;
  * per-slot positions are tracked HOST-side (``pool.pos``) — reading a
    position never costs a device sync, and the model-call API takes
    positions as an argument instead of carrying them in the cache dict;
  * per-slot rollback is row replication: after block verification the
    surviving draft row's cache is broadcast across the slot's rows (one
    arena-wide gather for all slots at once, ``rollback_rows``);
  * ``ensure_buf`` grows every arena to a longer buffer (zero-padded on
    the time axis) when a larger request is admitted; buffer length only
    ever grows, mirroring the scheduler's monotone buffer policy.

int8 arenas (DESIGN.md §11): ``quant=True`` stores each arena as four
leaves — int8 ``k``/``v`` plus f32 per-KV-vector scales ``k_s``/``v_s``
with a trailing singleton axis, so every arena op here (row gather /
scatter on axis 1, time growth on axis 3) applies uniformly to all
leaves.  The slots model calls quantize on write and dequantize inside
the attention reads; ``write_prefill`` quantizes dense prefill caches on
install.

Positions live in TWO places (DESIGN.md §8): the host mirror
(``pool.pos``) is authoritative for admission/allocation and sizing
decisions, and a lazily materialized device copy (``pos_device()``)
feeds the fused round program, which advances positions in-program and
hands back the updated array (``adopt_round_device``).  Host-side
lifecycle writes (alloc/release/prefill) update the device copy
PER SLOT (``_touch_pos`` — one ``.at[slot].set`` element write), so
admitting or releasing one request never re-uploads every live slot's
positions; the fused round refreshes the host mirror for the slots it
advanced from its packed result (``refresh_pos_host``), so the two
views never drift.
"""

from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.registry import init_cache


@jax.jit
def _gather_rows(leaf, idx):
    return jnp.take(leaf, idx, axis=1)


@functools.partial(jax.jit, static_argnames=("r0",))
def _scatter_rows(leaf, rows, r0: int):
    return jax.lax.dynamic_update_slice_in_dim(leaf, rows, r0, axis=1)


@jax.jit
def _grow_time(new_leaf, old_leaf):
    t_old = old_leaf.shape[3]
    return jax.lax.dynamic_update_slice_in_dim(
        new_leaf, old_leaf, 0, axis=3) if t_old else new_leaf


class CachePool:
    """Multi-model slot arena; see module docstring for the contract."""

    def __init__(self, cfgs: Dict[str, ModelConfig], num_slots: int,
                 rows_per_slot: int, buf_len: int, quant: bool = False):
        assert num_slots >= 1 and rows_per_slot >= 1
        for cfg in cfgs.values():
            assert not cfg.sliding_window, \
                "CachePool: non-ring (full-attention) caches only"
        self.cfgs = dict(cfgs)
        self.num_slots = num_slots
        self.rows_per_slot = rows_per_slot
        self.buf_len = buf_len
        self.quant = quant
        self.caches = {name: self._init_arena(cfg, buf_len)
                       for name, cfg in self.cfgs.items()}
        # Host-side per-slot decode position (== tokens whose KV is live).
        self.pos = np.zeros(num_slots, np.int64)
        # Device copy of ``pos`` for the fused round program; rebuilt
        # lazily after any host-side position write (DESIGN.md §8).
        self._pos_dev = None
        self._free = list(range(num_slots))

    def _init_arena(self, cfg: ModelConfig, buf_len: int) -> dict:
        c = init_cache(cfg, self.num_slots * self.rows_per_slot, buf_len)
        arena = {"k": c["k"], "v": c["v"]}   # positions live host-side
        if self.quant:
            # int8 leaves + per-KV-vector f32 scales (trailing-1 axis).
            sshape = c["k"].shape[:-1] + (1,)
            arena = {"k": jnp.zeros(c["k"].shape, jnp.int8),
                     "v": jnp.zeros(c["v"].shape, jnp.int8),
                     "k_s": jnp.zeros(sshape, jnp.float32),
                     "v_s": jnp.zeros(sshape, jnp.float32)}
        return arena

    # -- slot lifecycle ----------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError(
                f"CachePool: all {self.num_slots} slots in use")
        slot = min(self._free)
        self._free.remove(slot)
        self.pos[slot] = 0
        self._touch_pos(slot)
        return slot

    def release(self, slot: int) -> None:
        assert 0 <= slot < self.num_slots and slot not in self._free
        self.pos[slot] = 0
        self._touch_pos(slot)
        self._free.append(slot)

    def set_pos(self, slot: int, pos: int) -> None:
        """Record a slot's new decode position (host mirror + per-slot
        device touch) — the host-driven round's position write."""
        self.pos[slot] = int(pos)
        self._touch_pos(slot)

    def rows_of(self, slot: int) -> np.ndarray:
        r = self.rows_per_slot
        return np.arange(slot * r, (slot + 1) * r)

    # -- buffer growth -----------------------------------------------------
    def ensure_buf(self, buf_len: int) -> None:
        """Grow every arena's time axis to at least ``buf_len``.  Existing
        KV content (all live positions) is preserved; new tail is zero."""
        if buf_len <= self.buf_len:
            return
        for name, cfg in self.cfgs.items():
            fresh = self._init_arena(cfg, buf_len)
            old = self.caches[name]
            self.caches[name] = {kk: _grow_time(fresh[kk], old[kk])
                                 for kk in fresh}
        self.buf_len = buf_len

    # -- cache content ops -------------------------------------------------
    def write_prefill(self, name: str, slot: int, cache: dict,
                      pos: int) -> None:
        """Install a freshly prefilled ``(layers, rows_per_slot, ...)``
        cache into ``slot``'s rows of arena ``name``; ``pos`` is the
        number of prefilled tokens.  The prefill cache must have been
        built at the pool's current ``buf_len``.  Quant pools accept a
        dense {k, v} prefill cache and quantize it on install."""
        arena = self.caches[name]
        assert cache["k"].shape[3] == self.buf_len, \
            "prefill cache buffer != pool buffer"
        if self.quant and "k_s" not in cache:
            from repro.serving.quant import quantize_kv
            kq, ks = quantize_kv(cache["k"])
            vq, vs = quantize_kv(cache["v"])
            cache = {"k": kq, "v": vq, "k_s": ks, "v_s": vs}
        r0 = slot * self.rows_per_slot
        self.caches[name] = {kk: _scatter_rows(arena[kk], cache[kk], r0=r0)
                             for kk in arena}
        self.pos[slot] = pos
        self._touch_pos(slot)

    def update(self, name: str, cache: dict) -> None:
        """Adopt the arena returned by a slots model call."""
        self.caches[name] = {kk: cache[kk] for kk in self.caches[name]}

    def rollback_rows(self, row_src: np.ndarray) -> None:
        """Arena-wide row replication: row i of every cache becomes row
        ``row_src[i]``.  Callers build ``row_src`` so each rolled-back
        slot's rows all point at its surviving row and every other row
        points at itself."""
        assert row_src.shape == (self.num_slots * self.rows_per_slot,)
        idx = jnp.asarray(row_src, jnp.int32)
        for name, arena in self.caches.items():
            self.caches[name] = {kk: _gather_rows(arena[kk], idx)
                                 for kk in arena}

    # -- fused-round device state (DESIGN.md §8) ---------------------------
    def _touch_pos(self, slot: int) -> None:
        """Per-slot device-position update after a host lifecycle write:
        one ``.at[slot].set`` element write instead of invalidating (and
        re-uploading) the whole position array.  No-op while the device
        copy has never been materialized."""
        if self._pos_dev is not None:
            self._pos_dev = self._pos_dev.at[slot].set(
                jnp.int32(int(self.pos[slot])))

    def pos_device(self) -> jax.Array:
        """(num_slots,) i32 device positions for the fused round program.
        Materialized from the host mirror once; afterwards the array
        handed back by the previous round (plus per-slot lifecycle
        touches) is reused, so the steady-state round uploads nothing."""
        if self._pos_dev is None:
            self._pos_dev = jnp.asarray(self.pos, jnp.int32)
        return self._pos_dev

    def adopt_round_device(self, caches: Dict[str, dict],
                           pos_dev: jax.Array) -> None:
        """Adopt a fused round program's DEVICE outputs: the per-model
        {k, v} arenas (the donated input buffers are dead — callers must
        never touch them again) and the advanced device positions.
        Deliberately host-async: callers may dispatch more device work
        (admission prefills, §9) against the adopted arrays before the
        round's packed result is fetched; the host mirror stays stale
        for the advanced slots until ``refresh_pos_host``."""
        assert set(caches) == set(self.caches)
        for name, c in caches.items():
            self.caches[name] = {kk: c[kk] for kk in self.caches[name]}
        self._pos_dev = pos_dev

    def refresh_pos_host(self, pos_host: np.ndarray, slots) -> None:
        """Refresh the host position mirror for ``slots`` from a fused
        round's packed result.  Only the slots the round advanced are
        written — slots admitted while the round ran already hold their
        post-prefill positions host-side, and the round's packed ``pos``
        (snapshotted at dispatch) would clobber them."""
        for s in slots:
            self.pos[s] = int(pos_host[s])

    def row_positions(self, default: int = 0) -> np.ndarray:
        """(num_slots * rows_per_slot,) per-row positions for the slots
        model calls; free slots get ``default``."""
        per_slot = self.pos.copy()
        for s in self._free:
            per_slot[s] = default
        return np.repeat(per_slot, self.rows_per_slot).astype(np.int32)
