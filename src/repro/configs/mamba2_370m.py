"""mamba2-370m [ssm] — attention-free SSD (state-space duality).
[arXiv:2405.21060]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,              # attention-free
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_head_dim=64,          # d_inner 2048 -> 32 SSM heads
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=64,             # bounds intra-chunk quadratic memory
)
