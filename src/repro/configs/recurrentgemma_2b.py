"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1 attn : 2
recurrent.  [arXiv:2402.19427]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,            # 8 x (rec, rec, attn) + 2 trailing rec
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,           # MQA
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    pattern_rec=2,
    local_window=2048,
    lru_width=2560,
)
