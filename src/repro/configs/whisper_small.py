"""whisper-small [audio] — enc-dec transformer backbone, conv frontend
stubbed (precomputed frame embeddings).  [arXiv:2212.04356]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,            # decoder layers
    encoder_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,          # full MHA
    head_dim=64,
    d_ff=3072,
    vocab_size=51_865,        # padded to 51968 internally
    max_decoder_len=448,
)
