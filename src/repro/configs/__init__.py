"""Architecture config registry: the 10 assigned architectures (+ the
paper-scale spec-dec pair) selectable via ``--arch <id>``."""

from __future__ import annotations

import importlib

from repro.configs.shapes import (
    LONG_CONTEXT_OK,
    SHAPES,
    InputShape,
    cache_specs,
    input_specs,
    supports_shape,
)
from repro.models.config import ModelConfig

_MODULES = {
    "whisper-small": "whisper_small",
    "granite-8b": "granite_8b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "mamba2-370m": "mamba2_370m",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "llama3-405b": "llama3_405b",
    "mixtral-8x22b": "mixtral_8x22b",
    "smollm-360m": "smollm_360m",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "granite-34b": "granite_34b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_configs() -> dict:
    return {name: get_config(name) for name in ARCH_NAMES}


# Paper-scale speculative decoding pair (target ~= 100M-class llama,
# drafter ~= 20M-class), used by examples and the end-to-end driver.
PAPER_TARGET = ModelConfig(
    name="gls-target-100m", family="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
    vocab_size=8192, dtype="float32",
)
PAPER_DRAFTER = ModelConfig(
    name="gls-drafter-20m", family="dense", num_layers=4, d_model=384,
    num_heads=6, num_kv_heads=2, head_dim=64, d_ff=1024,
    vocab_size=8192, dtype="float32",
)

__all__ = [
    "ARCH_NAMES",
    "LONG_CONTEXT_OK",
    "PAPER_DRAFTER",
    "PAPER_TARGET",
    "SHAPES",
    "InputShape",
    "all_configs",
    "cache_specs",
    "get_config",
    "input_specs",
    "supports_shape",
]
