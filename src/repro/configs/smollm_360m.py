"""smollm-360m [dense] — small llama-arch; also the drafter in the
paper-scale speculative-decoding example.  [hf:HuggingFaceTB/SmolLM-135M]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,           # GQA
    head_dim=64,
    d_ff=2560,
    vocab_size=49_152,
)
