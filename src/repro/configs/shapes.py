"""The four assigned input shapes and ShapeDtypeStruct input specs.

  train_4k     seq_len=4096    global_batch=256   (training)
  prefill_32k  seq_len=32768   global_batch=32    (inference prefill)
  decode_32k   seq_len=32768   global_batch=128   (decode: 1 token, cache=seq)
  long_500k    seq_len=524288  global_batch=1     (long-context decode)

``input_specs(cfg, shape)`` returns {name: ShapeDtypeStruct} stand-ins for
every model input — weak-type-correct, shardable, no device allocation.
Decode shapes describe the *step* inputs only; the KV/SSM cache spec comes
from ``cache_specs``.
"""

from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# Sub-quadratic-decode archs eligible for long_500k (see DESIGN.md).
LONG_CONTEXT_OK = ("mamba2-370m", "recurrentgemma-2b", "mixtral-8x22b")


def supports_shape(cfg: ModelConfig, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return cfg.name in LONG_CONTEXT_OK
    return True


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _token_len(cfg: ModelConfig, seq: int) -> int:
    """Decoder token length: enc-dec archs cap at max_decoder_len (the long
    dimension for them is the encoder/frames side)."""
    if cfg.family == "encdec":
        return min(seq, cfg.max_decoder_len)
    return seq


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Model inputs for one (arch, shape) combination."""
    b, s = shape.global_batch, shape.seq_len
    act = cfg.activation_dtype
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": _sds((b, _token_len(cfg, s)), jnp.int32)}
        if cfg.family == "encdec":
            specs["frames"] = _sds((b, s, cfg.d_model), act)
        if cfg.family == "vlm":
            specs["images"] = _sds((b, cfg.num_image_tokens, cfg.d_model), act)
        if shape.kind == "train":
            specs["targets"] = _sds(specs["tokens"].shape, jnp.int32)
        return specs
    # decode: one new token against a cache of length seq_len
    return {"tokens": _sds((b, 1), jnp.int32)}


def cache_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct pytree matching registry.init_cache(cfg, b, seq)."""
    from repro.models import registry

    def spec_of(leaf):
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype)

    cache = jax.eval_shape(
        lambda: registry.init_cache(cfg, shape.global_batch, shape.seq_len))
    return jax.tree.map(spec_of, cache)
