"""Gumbel-max List Sampling (GLS) — the paper's core contribution (Sec. 3).

Communication-free coupling between one target sample ``Y ~ q`` and a list
of ``K`` i.i.d. proposal samples ``X^(1..K) ~ p`` built from shared
exponential random numbers ``S_i^(k) = -ln U_i^(k)``:

    X^(k) = argmin_i  S_i^(k) / p_i              (per-draft race)
    Y     = argmin_i  min_k S_i^(k) / q_i        (target races over all K)

Everything here is pure JAX (jit/vmap/grad-safe).  Numerics are done in
log-space where it matters: ``S/p = exp(log S - log p)`` and argmin of the
ratio equals argmin of ``log S - log p``, which avoids overflow for tiny
probabilities.  Zero-probability symbols get ``-inf`` log-prob and are
never selected (their race time is +inf).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "exponential_races",
    "gls_sample",
    "gls_sample_heterogeneous",
    "gls_conditional_encoder",
    "gls_conditional_decoder",
    "gls_importance_sample",
    "GLSSample",
]

_NEG_INF = -jnp.inf


class GLSSample(NamedTuple):
    """Result of one GLS draw.

    Attributes:
      y: int32 — Bob's (target) sample index.
      x: int32[K] — Alice's (proposal) sample indices.
      accept: bool — whether ``y`` appears in ``x``.
    """

    y: jax.Array
    x: jax.Array
    accept: jax.Array


def _log_uniform(key: jax.Array, shape) -> jax.Array:
    """log(U) for U ~ Unif(0,1], safe against log(0)."""
    u = jax.random.uniform(key, shape, minval=jnp.finfo(jnp.float32).tiny, maxval=1.0)
    return jnp.log(u)


def exponential_races(key: jax.Array, k: int, n: int) -> jax.Array:
    """K sets of N shared race times in log-space: log S_i^(k), S ~ Exp(1).

    ``S = -ln U`` so ``log S = log(-log U)``.  Returned shape ``(k, n)``.
    """
    log_u = _log_uniform(key, (k, n))
    return jnp.log(-log_u)


def _race_argmin(log_s: jax.Array, log_p: jax.Array) -> jax.Array:
    """argmin_i S_i / p_i computed in log space along the last axis.

    ``log(S_i/p_i) = log S_i - log p_i``; zero-prob symbols (log_p = -inf)
    yield +inf and lose the race.
    """
    score = log_s - log_p
    # Where p == 0 the score is +inf (or nan if log_s is -inf too); force +inf.
    score = jnp.where(jnp.isnan(score), jnp.inf, score)
    return jnp.argmin(score, axis=-1).astype(jnp.int32)


def _safe_log(p: jax.Array) -> jax.Array:
    return jnp.where(p > 0, jnp.log(jnp.maximum(p, jnp.finfo(p.dtype).tiny)), _NEG_INF)


@functools.partial(jax.jit, static_argnames=("k",))
def gls_sample(key: jax.Array, p: jax.Array, q: jax.Array, k: int) -> GLSSample:
    """One GLS draw (Algorithm 1 of the paper).

    Args:
      key: PRNG key — the *shared* randomness between Alice and Bob.
      p: proposal distribution, shape (N,).
      q: target distribution, shape (N,).
      k: number of proposal samples K.

    Returns:
      GLSSample(y, x[K], accept).
    """
    log_s = exponential_races(key, k, p.shape[-1])  # (K, N)
    log_p = _safe_log(p)
    log_q = _safe_log(q)
    x = _race_argmin(log_s, log_p[None, :])  # (K,)
    # Target: min over k first (in log space min of S == min of log S).
    y = _race_argmin(jnp.min(log_s, axis=0), log_q)
    accept = jnp.any(x == y)
    return GLSSample(y=y, x=x, accept=accept)


@jax.jit
def gls_sample_heterogeneous(key: jax.Array, ps: jax.Array, q: jax.Array) -> GLSSample:
    """GLS with K *different* proposal distributions (paper Prop. 5).

    Args:
      ps: (K, N) stack of proposal distributions.
      q: (N,) target.
    """
    kk, n = ps.shape
    log_s = exponential_races(key, kk, n)
    x = _race_argmin(log_s, _safe_log(ps))  # row-wise race, (K,)
    y = _race_argmin(jnp.min(log_s, axis=0), _safe_log(q))
    accept = jnp.any(x == y)
    return GLSSample(y=y, x=x, accept=accept)


# ---------------------------------------------------------------------------
# Conditional GLS (paper Sec. 5.2) — encoder/decoder split for compression.
# The encoder and decoders hold the SAME race table (same key); the encoder
# conditions on the source A, each decoder k races only its own sheet k
# against its private target p(.|z_k).
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k",))
def gls_conditional_encoder(key: jax.Array, q_given_a: jax.Array, k: int) -> jax.Array:
    """Encoder side: Y = argmin_i min_k S_i^(k) / q_i(a).  Returns int32."""
    log_s = exponential_races(key, k, q_given_a.shape[-1])
    return _race_argmin(jnp.min(log_s, axis=0), _safe_log(q_given_a))


@functools.partial(jax.jit, static_argnames=("k", "which"))
def gls_conditional_decoder(
    key: jax.Array, p_given_z: jax.Array, k: int, which: int
) -> jax.Array:
    """Decoder ``which`` (0-based): X = argmin_i S_i^(which) / p_i(z)."""
    log_s = exponential_races(key, k, p_given_z.shape[-1])
    return _race_argmin(log_s[which], _safe_log(p_given_z))


# ---------------------------------------------------------------------------
# Importance-sampling extension (paper App. C) — continuous targets.
# N i.i.d. prior samples U_1..U_N ~ p_W plus unnormalized weights stand in
# for an enumerated alphabet; races run over normalized weights.
# ---------------------------------------------------------------------------


def gls_importance_sample(
    key: jax.Array,
    log_w_q: jax.Array,
    log_w_p: jax.Array,
    k: int,
) -> GLSSample:
    """GLS over importance-weighted atoms.

    Args:
      log_w_q: (N,) unnormalized log importance weights for the encoder
        target, ``log p_{B|A}(B_i|a) - log p_B(B_i)``.
      log_w_p: (K, N) per-decoder unnormalized log weights,
        ``log p_{B|Z}(B_i|z_k) - log p_B(B_i)``.  -inf marks masked atoms
        (e.g. bin mismatch 1{l_i != l_j}).
      k: number of decoders.

    Note: argmin of S/λ is invariant to the normalizing constant of λ, so
    we can race directly on unnormalized weights.
    """
    n = log_w_q.shape[-1]
    log_s = exponential_races(key, k, n)
    y = _race_argmin(jnp.min(log_s, axis=0), log_w_q)
    x = _race_argmin(log_s, log_w_p)
    accept = jnp.any(x == y)
    return GLSSample(y=y, x=x, accept=accept)


# ---------------------------------------------------------------------------
# Batched helpers used by the spec-dec engine and the benchmarks.
# ---------------------------------------------------------------------------


def gls_sample_batch(key: jax.Array, p: jax.Array, q: jax.Array, k: int, batch: int):
    """vmap of gls_sample over `batch` independent trials (fresh keys)."""
    keys = jax.random.split(key, batch)
    return jax.vmap(lambda kk: gls_sample(kk, p, q, k))(keys)
