"""Core coupling library: Gumbel-max List Sampling and its bounds."""

from repro.core.bounds import (
    conditional_lml_bound,
    iid_draft_acceptance_upper,
    lml_bound,
    lml_conditional_bound,
    lml_relaxed_bound,
    maximal_coupling_acceptance,
    single_draft_gumbel_bound,
    tv_distance,
    wz_error_upper_bound,
)
from repro.core.gls import (
    GLSSample,
    exponential_races,
    gls_conditional_decoder,
    gls_conditional_encoder,
    gls_importance_sample,
    gls_sample,
    gls_sample_batch,
    gls_sample_heterogeneous,
)

__all__ = [
    "GLSSample",
    "exponential_races",
    "gls_conditional_decoder",
    "gls_conditional_encoder",
    "gls_importance_sample",
    "gls_sample",
    "gls_sample_batch",
    "gls_sample_heterogeneous",
    "conditional_lml_bound",
    "iid_draft_acceptance_upper",
    "lml_bound",
    "lml_conditional_bound",
    "lml_relaxed_bound",
    "maximal_coupling_acceptance",
    "single_draft_gumbel_bound",
    "tv_distance",
    "wz_error_upper_bound",
]
