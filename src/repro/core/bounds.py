"""Theoretical bounds from the paper.

* ``lml_bound`` — Theorem 1 (List Matching Lemma), eq. (3).
* ``lml_conditional_bound`` — Theorem 1 eq. (4): Pr[accept | Y=j].
* ``lml_relaxed_bound`` — the relaxed form  Σ_j q_j (1 + q_j/(K p_j))^-1
  derived at the end of App. A.2.
* ``conditional_lml_bound`` — Theorem 2 (compression setting).
* ``tv_distance`` / ``maximal_coupling_acceptance`` — classical 1 - d_TV.
* ``single_draft_gumbel_bound`` — Daliri et al. (1-TV)/(1+TV).
* ``iid_draft_acceptance_upper`` — Σ_j min(q_j, 1-(1-p_j)^K), the optimal
  *with-communication* upper bound for K i.i.d. drafts (used in place of
  the paper's LP optimum in Fig. 6; see DESIGN.md §6).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "tv_distance",
    "maximal_coupling_acceptance",
    "single_draft_gumbel_bound",
    "lml_bound",
    "lml_conditional_bound",
    "lml_relaxed_bound",
    "conditional_lml_bound",
    "iid_draft_acceptance_upper",
    "wz_error_upper_bound",
]


def tv_distance(p: jax.Array, q: jax.Array) -> jax.Array:
    """Total variation distance between two discrete distributions."""
    return 0.5 * jnp.sum(jnp.abs(p - q), axis=-1)


def maximal_coupling_acceptance(p: jax.Array, q: jax.Array) -> jax.Array:
    """Optimal single-sample matching probability WITH communication."""
    return 1.0 - tv_distance(p, q)


def single_draft_gumbel_bound(p: jax.Array, q: jax.Array) -> jax.Array:
    """Daliri et al. communication-free bound: (1-TV)/(1+TV)."""
    tv = tv_distance(p, q)
    return (1.0 - tv) / (1.0 + tv)


def _ratio_grid(v: jax.Array) -> jax.Array:
    """r[i, j] = v_i / v_j with 0/0 -> inf kept out of the support."""
    num = v[:, None]
    den = v[None, :]
    r = num / jnp.where(den > 0, den, 1.0)
    # Columns j with v_j == 0 never have Y=j / X=j; mask handled by caller.
    return r


def lml_bound(p: jax.Array, q: jax.Array, k: int) -> jax.Array:
    """Theorem 1 eq. (3):

    Pr[Y in {X}] >= Σ_j  K / Σ_i [ max(q_i/q_j, p_i/p_j) + (K-1) q_i/q_j ].

    Terms with q_j == 0 contribute nothing (Y=j has probability 0); terms
    with p_j == 0 make the i=argmax p_i ratio blow up, correctly driving
    the j-th summand to 0.
    """
    qr = _ratio_grid(q)  # q_i / q_j at [i, j]
    pr = _ratio_grid(p)
    # Where p_j == 0, p_i/p_j should be +inf for any p_i > 0.
    pj_zero = (p <= 0)[None, :]
    pr = jnp.where(pj_zero & (p[:, None] > 0), jnp.inf, pr)
    qj_zero = (q <= 0)[None, :]
    qr = jnp.where(qj_zero & (q[:, None] > 0), jnp.inf, qr)
    denom = jnp.sum(jnp.maximum(qr, pr) + (k - 1) * qr, axis=0)  # over i, per j
    summand = k / denom
    summand = jnp.where(q > 0, summand, 0.0)
    return jnp.sum(summand)


def lml_conditional_bound(p_j: jax.Array, q_j: jax.Array, k: int) -> jax.Array:
    """Theorem 1 eq. (4): Pr[accept | Y=j] >= (1 + q_j/(K p_j))^-1."""
    return 1.0 / (1.0 + q_j / (k * jnp.maximum(p_j, jnp.finfo(jnp.float32).tiny)))


def lml_relaxed_bound(p: jax.Array, q: jax.Array, k: int) -> jax.Array:
    """Relaxed LML (end of App. A.2):  Σ_j q_j (1 + q_j/(K p_j))^-1."""
    terms = q * lml_conditional_bound(p, q, k)
    return jnp.sum(jnp.where((q > 0) & (p > 0), terms, 0.0))


def conditional_lml_bound(q_j_a: jax.Array, p_j_zk: jax.Array, k: int) -> jax.Array:
    """Theorem 2:  Pr[match | Y=j, A=a, Z^K] >= Σ_k (K + q_j(a)/p_j(z_k))^-1.

    Args:
      q_j_a: scalar — encoder target prob of the selected index.
      p_j_zk: (K,) — each decoder's target prob of that index.
    """
    tiny = jnp.finfo(jnp.float32).tiny
    return jnp.sum(1.0 / (k + q_j_a / jnp.maximum(p_j_zk, tiny)))


def iid_draft_acceptance_upper(p: jax.Array, q: jax.Array, k: int) -> jax.Array:
    """Upper bound on acceptance for ANY scheme with K i.i.d. drafts:

    Pr[Y in list] <= Σ_j min(q_j, 1 - (1-p_j)^K)

    (the list contains symbol j with probability 1-(1-p_j)^K; a coupling
    cannot beat the pointwise min). Used as the Fig.-6 reference curve.
    """
    return jnp.sum(jnp.minimum(q, 1.0 - (1.0 - p) ** k))


def wz_error_upper_bound(info_density: jax.Array, k: int, l_max: int) -> jax.Array:
    """Proposition 4: Pr[err] <= 1 - E[(1 + 2^{i(W;A|T)} / (K L_max))^-1].

    Args:
      info_density: samples of i(W;A|T) in *bits* (log2), any shape.
    """
    inner = 1.0 / (1.0 + jnp.exp2(info_density) / (k * l_max))
    return 1.0 - jnp.mean(inner)
