"""Pure-jnp oracle for the GLS race kernel.

Given shared race times in log space (log S, S~Exp(1)), per-draft log
proposal probs and per-draft log target probs, compute

  x[b, k] = argmin_n  exp(log_s[b,k,n] - log_p[b,k,n])     (draft races)
  y[b]    = argmin_n  min_{k active}
                      exp(log_s[b,k,n] - log_q[b,k,n])     (target race)

-inf log-probs mark zero-probability symbols (never selected).  Ties are
broken toward the lower index (argmin semantics), matching the kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gls_row_race_ref(log_s: jax.Array, log_q: jax.Array):
    """Per-row race statistics: (rmin (B, K) f32, rarg (B, K) i32) of
    score = log_s - log_q with -inf log-probs masked to +inf."""
    score = log_s - log_q
    score = jnp.where(jnp.isfinite(log_q), score, jnp.inf)
    return (jnp.min(score, axis=-1),
            jnp.argmin(score, axis=-1).astype(jnp.int32))


def gls_binned_race_ref(log_s: jax.Array, log_q: jax.Array,
                        bins: jax.Array, *, l_max: int):
    """Per-(row, sheet, bin) race statistics, the ``gls_binned_race``
    oracle: (bmin (B, K, l_max) f32, barg (B, K, l_max) i32) of
    score = log_s - log_q restricted to atoms with ``bins == l``, with
    -inf log-weights masked to +inf.  A bin with no live atom reports
    (inf, 0).  The per-bin Python loop mirrors the kernel's unrolled
    accumulator update so reduction order (and thus tie-breaking) is
    identical."""
    score = log_s - log_q
    score = jnp.where(jnp.isfinite(log_q), score, jnp.inf)
    mins, args = [], []
    for l in range(l_max):
        s_l = jnp.where((bins == l)[:, None, :], score, jnp.inf)
        # One reduction pass per bin: the min VALUE is the element at the
        # argmin (exact — min returns one of its inputs), so gather it
        # instead of paying a second full reduction.  An empty bin (all
        # +inf) yields argmin 0 and gathers +inf, matching the kernel's
        # untouched (inf, 0) accumulator.
        arg = jnp.argmin(s_l, axis=-1).astype(jnp.int32)
        mins.append(jnp.take_along_axis(s_l, arg[..., None], axis=-1)[..., 0])
        args.append(arg)
    return jnp.stack(mins, axis=-1), jnp.stack(args, axis=-1)


def gls_race_ref(log_s: jax.Array, log_p: jax.Array, log_q: jax.Array,
                 active: jax.Array):
    """log_s/log_p/log_q: (B, K, N) f32; active: (B, K) bool.

    Returns (x (B, K) i32, y (B,) i32).
    """
    draft_score = log_s - log_p
    draft_score = jnp.where(jnp.isfinite(log_p), draft_score, jnp.inf)
    x = jnp.argmin(draft_score, axis=-1).astype(jnp.int32)

    tgt_score = log_s - log_q
    tgt_score = jnp.where(jnp.isfinite(log_q), tgt_score, jnp.inf)
    tgt_score = jnp.where(active[..., None], tgt_score, jnp.inf)
    flat = jnp.min(tgt_score, axis=1)           # min over k: (B, N)
    y = jnp.argmin(flat, axis=-1).astype(jnp.int32)
    return x, y
