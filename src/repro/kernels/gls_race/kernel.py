"""Pallas TPU kernel for the GLS race (the paper's verification hot op).

TPU adaptation (DESIGN.md §3): the vocabulary axis (up to 256k) is tiled
into VMEM-sized blocks (lane-aligned, multiples of 128); the
``log S - log p`` transform is fused with a running (min, argmin)
reduction held in VMEM scratch, so the (K, N) race table never makes a
second HBM round trip.  The K-way min for the target rides the sublane
dimension of the same pass.

``gls_race`` grid: (B, N // TILE_N); each program reduces one vocab tile
for one batch row.  Scratch carries the running draft minima (K,) and
the target minimum (scalar) across the vocab-tile loop (sequential minor
grid axis).

``gls_row_race`` (the block-verification hot path) additionally tiles
the ROW axis: the vocab tile shrinks to fit the actual vocabulary (a
128-symbol bench vocab must not be padded to the 2048 default — 16x
wasted compute), several batch rows share one program (grid invocations
are the dominant cost in interpret mode and amortize DMA setup on TPU),
and the row count is bucketed up to the row-block multiple so nearby
batch sizes (L+1 for one request, S*(L+1) for a fused round) reuse one
compiled kernel instead of recompiling per shape.

``gls_binned_race`` (the Wyner–Ziv compression hot path, DESIGN.md §10)
reuses the row-race tiling but keeps ``l_max`` running (min, argmin)
accumulators per (row, sheet) — one per bin id — so a single pass over
the atom axis resolves the encoder race and every bin-masked decoder
race of a batched compression round.

Execution-mode contract (DESIGN.md §11): every public entry point takes
``interpret: bool | None``.  ``None`` (the default) autodetects — the
kernel compiles on backends with Pallas lowering (TPU/GPU) and falls
back to the bit-identical jnp reference elsewhere (CPU), so callers
never hard-code the mode.  ``True`` forces the Pallas interpreter (the
kernel BODY runs on any backend — what the kernel-vs-ref tests
exercise); ``False`` forces compiled lowering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.gls_race.ref import (
    gls_binned_race_ref,
    gls_race_ref,
    gls_row_race_ref,
)
from repro.kernels.pallas_mode import has_compiled_pallas, resolve_pallas_mode

DEFAULT_TILE_N = 2048
# Per-operand VMEM budget for one (ROW_BLOCK, K, TILE_N) f32 input block.
_ROW_VMEM_BYTES = 2 * 1024 * 1024
# Row-bucket granularity: B is padded up to a multiple of this (capped by
# the VMEM budget) so the kernel compiles once per (K, N) rather than
# once per batch size.
_ROW_BLOCK = 8


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def resolve_race_mode(interpret: bool | None = None) -> str:
    """Race-family alias of ``pallas_mode.resolve_pallas_mode``:
    "compiled" | "interpret" | "fallback" (the fallback is bit-identical
    to the kernel, so the switch is observable only through timing and
    dispatch accounting)."""
    return resolve_pallas_mode(interpret)


def _kernel(log_s_ref, log_p_ref, log_q_ref, active_ref,
            x_ref, y_ref,
            dmin_ref, dargs_ref, tmin_ref, targ_ref,
            *, tile_n: int, n_tiles: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        dmin_ref[...] = jnp.full_like(dmin_ref, jnp.inf)
        dargs_ref[...] = jnp.zeros_like(dargs_ref)
        tmin_ref[...] = jnp.full_like(tmin_ref, jnp.inf)
        targ_ref[...] = jnp.zeros_like(targ_ref)

    log_s = log_s_ref[0]          # (K, TILE_N)
    log_p = log_p_ref[0]
    log_q = log_q_ref[0]
    active = active_ref[0]        # (K, 1) f32 mask (1=active)

    # --- draft races: per-k argmin of log_s - log_p ---
    dscore = log_s - log_p
    dscore = jnp.where(log_p > -jnp.inf, dscore, jnp.inf)
    tile_dmin = jnp.min(dscore, axis=1)                      # (K,)
    tile_darg = jnp.argmin(dscore, axis=1).astype(jnp.int32)
    tile_didx = t * tile_n + tile_darg
    better = tile_dmin < dmin_ref[:, 0]
    dmin_ref[:, 0] = jnp.where(better, tile_dmin, dmin_ref[:, 0])
    dargs_ref[:, 0] = jnp.where(better, tile_didx, dargs_ref[:, 0])

    # --- target race: argmin over (k, n) of log_s - log_q, active only ---
    tscore = log_s - log_q
    tscore = jnp.where(log_q > -jnp.inf, tscore, jnp.inf)
    tscore = jnp.where(active > 0, tscore, jnp.inf)
    col_min = jnp.min(tscore, axis=0)                        # (TILE_N,)
    tile_tmin = jnp.min(col_min)
    tile_targ = t * tile_n + jnp.argmin(col_min).astype(jnp.int32)
    tbetter = tile_tmin < tmin_ref[0, 0]
    tmin_ref[0, 0] = jnp.where(tbetter, tile_tmin, tmin_ref[0, 0])
    targ_ref[0, 0] = jnp.where(tbetter, tile_targ, targ_ref[0, 0])

    @pl.when(t == n_tiles - 1)
    def _emit():
        x_ref[0, :] = dargs_ref[:, 0]
        y_ref[0, 0] = targ_ref[0, 0]


def _row_kernel(log_s_ref, log_q_ref,
                rmin_out_ref, rarg_out_ref,
                rmin_ref, rarg_ref,
                *, tile_n: int, n_tiles: int):
    """Per-row (min, argmin) of the race table ``log_s - log_q``.

    The target side of Algorithm 2 needs per-(step, draft) row statistics
    — the evolving ``active`` mask is applied OUTSIDE, on (L+1, K)
    scalars — so one batched pass over (B, K, N) serves the whole
    verification block (DESIGN.md §3).  Blocks are (ROW_BLOCK, K, TILE_N)
    — a row block of batch rows reduces together in one program."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        rmin_ref[...] = jnp.full_like(rmin_ref, jnp.inf)
        rarg_ref[...] = jnp.zeros_like(rarg_ref)

    log_s = log_s_ref[...]        # (RB, K, TILE_N)
    log_q = log_q_ref[...]

    score = log_s - log_q
    score = jnp.where(log_q > -jnp.inf, score, jnp.inf)
    tile_min = jnp.min(score, axis=2)                        # (RB, K)
    tile_arg = jnp.argmin(score, axis=2).astype(jnp.int32)
    tile_idx = t * tile_n + tile_arg
    better = tile_min < rmin_ref[...]
    rmin_ref[...] = jnp.where(better, tile_min, rmin_ref[...])
    rarg_ref[...] = jnp.where(better, tile_idx, rarg_ref[...])

    @pl.when(t == n_tiles - 1)
    def _emit():
        rmin_out_ref[...] = rmin_ref[...]
        rarg_out_ref[...] = rarg_ref[...]


def _binned_kernel(log_s_ref, log_q_ref, bins_ref,
                   bmin_out_ref, barg_out_ref,
                   bmin_ref, barg_ref,
                   *, tile_n: int, n_tiles: int, l_max: int):
    """Per-(row, sheet, bin) (min, argmin) of ``log_s - log_q``.

    The Wyner–Ziv decoder races only atoms inside the transmitted bin
    (the ``1{l_i = M}`` indicator, paper App. C).  Which bin wins is not
    known until the encoder race resolves, so instead of masking to ONE
    bin this kernel reduces every bin in the same pass over the atom
    axis: the bin-id tile selects each atom into exactly one of the
    ``l_max`` running (min, argmin) accumulators.  One dispatch then
    serves the encoder race (min over sheets and bins) AND all K
    bin-masked decoder races (slice the winning bin afterwards) —
    DESIGN.md §10.2.  ``l_max`` is static and small (the rate is
    ``log2 l_max`` bits, ≤ 6 in every paper configuration), so the
    demux broadcasts over a bin axis instead of looping: one masked
    (RB, K, l_max, TILE_N) select, ONE (min, argmin) reduction over the
    atom lane, one accumulator update — a single sweep regardless of
    ``l_max``, where the per-bin loop paid ``l_max`` reduction passes
    over the same tile.
    """
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        bmin_ref[...] = jnp.full_like(bmin_ref, jnp.inf)
        barg_ref[...] = jnp.zeros_like(barg_ref)

    log_s = log_s_ref[...]        # (RB, K, TILE_N)
    log_q = log_q_ref[...]
    bins = bins_ref[...]          # (RB, TILE_N)

    score = log_s - log_q
    # isfinite, not `> -inf`: +inf garbage weights must stay dead on the
    # kernel exactly as on gls_binned_race_ref (bit-interchangeability).
    score = jnp.where(jnp.isfinite(log_q), score, jnp.inf)
    rb, k, _ = score.shape
    # Atom -> bin demux as one broadcast compare against the bin-id iota
    # (broadcasted_iota: 1D iota does not lower on TPU).  The atom axis
    # stays the lane dimension, so the reduction below vectorizes the
    # same way the row race does.
    bin_ids = jax.lax.broadcasted_iota(bins.dtype, (rb, k, l_max, tile_n), 2)
    s_all = jnp.where(bins[:, None, None, :] == bin_ids,
                      score[:, :, None, :], jnp.inf)
    tile_min = jnp.min(s_all, axis=3)                    # (RB, K, l_max)
    tile_arg = jnp.argmin(s_all, axis=3).astype(jnp.int32)
    tile_idx = t * tile_n + tile_arg
    # Strict < keeps cross-tile ties on the earlier tile; argmin keeps
    # in-tile ties on the lower lane — global ties break toward the
    # lower atom index, exactly like the reference.
    better = tile_min < bmin_ref[...]
    bmin_ref[...] = jnp.where(better, tile_min, bmin_ref[...])
    barg_ref[...] = jnp.where(better, tile_idx, barg_ref[...])

    @pl.when(t == n_tiles - 1)
    def _emit():
        bmin_out_ref[...] = bmin_ref[...]
        barg_out_ref[...] = barg_ref[...]


def _row_race_tiling(b: int, k: int, n: int, tile_n: int, vmem_mult: int = 1):
    """(tile_n, row_block, b_pad): lane-aligned vocab tile no larger than
    the (padded) vocab, and the largest row block that keeps one f32
    input operand inside the VMEM budget — bucketing B so every batch
    size in a bucket shares one compiled kernel (the grid is batch-
    fitted: ``b_pad // rb`` programs, never a fixed overcount).

    ``vmem_mult`` scales the budgeted working set for kernels whose
    largest live tile is a multiple of the input block — the binned
    race's single-sweep demux materializes (RB, K, l_max, TILE_N), so it
    budgets with ``vmem_mult=l_max``."""
    tile_n = min(tile_n, _round_up(n, 128))
    rb = max(1, _ROW_VMEM_BYTES // (k * tile_n * 4 * vmem_mult))
    rb = min(rb, _ROW_BLOCK)
    return tile_n, rb, _round_up(b, rb)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def gls_row_race(log_s: jax.Array, log_q: jax.Array, *,
                 tile_n: int = DEFAULT_TILE_N,
                 interpret: bool | None = None):
    """Per-row GLS race statistics.  log_s/log_q: (B, K, N) f32.

    Returns (rmin (B, K) f32, rarg (B, K) i32): the minimum race time and
    its vocab index for every (batch, draft) row.  ``-inf`` in log_q
    marks zero-probability symbols (never win).  Ties break toward the
    lower vocab index, matching ``jnp.argmin``.

    ``interpret=None`` autodetects per ``resolve_race_mode`` — compiled
    Pallas on TPU/GPU, the bit-identical ``gls_row_race_ref`` elsewhere.

    ``tile_n`` is an upper bound: the actual vocab tile shrinks to the
    lane-aligned vocabulary so small vocabs are not padded to the 2048
    default, and batch rows are blocked/bucketed per ``_row_race_tiling``
    (rows are independent, so padding rows changes no live output).
    """
    mode = resolve_race_mode(interpret)
    if mode == "fallback":
        return gls_row_race_ref(log_s, log_q)
    b, k, n = log_s.shape
    tile_n, rb, b_pad = _row_race_tiling(b, k, n, tile_n)
    pad_n = _round_up(n, tile_n) - n
    if pad_n or b_pad > b:
        log_s = jnp.pad(log_s, ((0, b_pad - b), (0, 0), (0, pad_n)),
                        constant_values=0.0)
        log_q = jnp.pad(log_q, ((0, b_pad - b), (0, 0), (0, pad_n)),
                        constant_values=jnp.float32(-jnp.inf))
    n_tiles = log_s.shape[2] // tile_n

    kernel = functools.partial(_row_kernel, tile_n=tile_n, n_tiles=n_tiles)
    rmin, rarg = pl.pallas_call(
        kernel,
        grid=(b_pad // rb, n_tiles),
        in_specs=[
            pl.BlockSpec((rb, k, tile_n), lambda i, t: (i, 0, t)),
            pl.BlockSpec((rb, k, tile_n), lambda i, t: (i, 0, t)),
        ],
        out_specs=[
            pl.BlockSpec((rb, k), lambda i, t: (i, 0)),
            pl.BlockSpec((rb, k), lambda i, t: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((b_pad, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((rb, k), jnp.float32),   # running row minima
            pltpu.VMEM((rb, k), jnp.int32),     # running row argmins
        ],
        interpret=(mode == "interpret"),
    )(log_s, log_q)
    return rmin[:b], rarg[:b]


@functools.partial(jax.jit,
                   static_argnames=("l_max", "tile_n", "interpret"))
def gls_binned_race(log_s: jax.Array, log_q: jax.Array, bins: jax.Array, *,
                    l_max: int, tile_n: int = None,
                    interpret: bool | None = None):
    """Bin-masked GLS race statistics (the Wyner–Ziv compression op).

    log_s/log_q: (B, K, N) f32; bins: (B, N) i32 with values in
    [0, l_max).  Returns (bmin (B, K, l_max) f32, barg (B, K, l_max)
    i32): for every (row, sheet, bin) the minimum race time
    ``log_s - log_q`` over the atoms whose bin id equals that bin, and
    its atom index.  ``-inf`` in log_q marks dead atoms (zero importance
    weight — never win, exactly like zero-prob symbols in
    ``gls_row_race``); a bin with no live atom reports (inf, 0).  Ties
    break toward the lower atom index, matching ``jnp.argmin``, so the
    kernel stays bit-interchangeable with ``gls_binned_race_ref``.

    ``interpret=None`` autodetects per ``resolve_race_mode`` — compiled
    Pallas on TPU/GPU, the bit-identical ``gls_binned_race_ref``
    elsewhere (callers that need the sequenced CPU shape instead make
    that structure decision themselves; see ``wz_round_batch``).

    Tiling contract (DESIGN.md §10.4): the atom axis is tiled like
    ``gls_row_race`` — lane-aligned vocab-fitted tiles no larger than
    ``tile_n`` (None = the ``DEFAULT_TILE_N`` default), so importance
    lists of 2^14..2^16 atoms stream through fixed VMEM; rows are
    blocked/bucketed by ``_row_race_tiling`` (rows are independent, pad
    rows carry -inf weights).  Atom-axis padding uses bin id ``l_max``
    (matches no real bin) plus -inf weights.  ``l_max`` is static: the
    accumulator is (ROW_BLOCK, K, l_max) VMEM scratch and the per-bin
    select loop unrolls at trace time.
    """
    mode = resolve_race_mode(interpret)
    if mode == "fallback":
        return gls_binned_race_ref(log_s, log_q, bins, l_max=l_max)
    b, k, n = log_s.shape
    tile_n, rb, b_pad = _row_race_tiling(
        b, k, n, DEFAULT_TILE_N if tile_n is None else tile_n,
        vmem_mult=l_max)
    pad_n = _round_up(n, tile_n) - n
    if pad_n or b_pad > b:
        log_s = jnp.pad(log_s, ((0, b_pad - b), (0, 0), (0, pad_n)),
                        constant_values=0.0)
        log_q = jnp.pad(log_q, ((0, b_pad - b), (0, 0), (0, pad_n)),
                        constant_values=jnp.float32(-jnp.inf))
        bins = jnp.pad(bins, ((0, b_pad - b), (0, pad_n)),
                       constant_values=l_max)
    n_tiles = log_s.shape[2] // tile_n

    kernel = functools.partial(_binned_kernel, tile_n=tile_n,
                               n_tiles=n_tiles, l_max=l_max)
    bmin, barg = pl.pallas_call(
        kernel,
        grid=(b_pad // rb, n_tiles),
        in_specs=[
            pl.BlockSpec((rb, k, tile_n), lambda i, t: (i, 0, t)),
            pl.BlockSpec((rb, k, tile_n), lambda i, t: (i, 0, t)),
            pl.BlockSpec((rb, tile_n), lambda i, t: (i, t)),
        ],
        out_specs=[
            pl.BlockSpec((rb, k, l_max), lambda i, t: (i, 0, 0)),
            pl.BlockSpec((rb, k, l_max), lambda i, t: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_pad, k, l_max), jnp.float32),
            jax.ShapeDtypeStruct((b_pad, k, l_max), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((rb, k, l_max), jnp.float32),  # running bin minima
            pltpu.VMEM((rb, k, l_max), jnp.int32),    # running bin argmins
        ],
        interpret=(mode == "interpret"),
    )(log_s, log_q, bins)
    return bmin[:b], barg[:b]


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def gls_race(log_s: jax.Array, log_p: jax.Array, log_q: jax.Array,
             active: jax.Array, *, tile_n: int = DEFAULT_TILE_N,
             interpret: bool | None = None):
    """log_s/log_p/log_q: (B, K, N) f32; active: (B, K) bool.

    Returns (x (B, K) i32, y (B,) i32).  ``interpret=None`` autodetects
    per ``resolve_race_mode``: compiled Pallas on TPU/GPU, the
    bit-identical ``gls_race_ref`` elsewhere; ``True`` forces the
    interpreter (kernel body on any backend).
    """
    mode = resolve_race_mode(interpret)
    if mode == "fallback":
        return gls_race_ref(log_s, log_p, log_q, active)
    b, k, n = log_s.shape
    if n % tile_n:
        pad = tile_n - n % tile_n
        neg = jnp.float32(-jnp.inf)
        log_s = jnp.pad(log_s, ((0, 0), (0, 0), (0, pad)),
                        constant_values=0.0)
        log_p = jnp.pad(log_p, ((0, 0), (0, 0), (0, pad)),
                        constant_values=neg)
        log_q = jnp.pad(log_q, ((0, 0), (0, 0), (0, pad)),
                        constant_values=neg)
        n = n + pad
    n_tiles = n // tile_n
    active_f = active.astype(jnp.float32)[..., None]  # (B, K, 1)

    kernel = functools.partial(_kernel, tile_n=tile_n, n_tiles=n_tiles)
    x, y = pl.pallas_call(
        kernel,
        grid=(b, n_tiles),
        in_specs=[
            pl.BlockSpec((1, k, tile_n), lambda i, t: (i, 0, t)),
            pl.BlockSpec((1, k, tile_n), lambda i, t: (i, 0, t)),
            pl.BlockSpec((1, k, tile_n), lambda i, t: (i, 0, t)),
            pl.BlockSpec((1, k, 1), lambda i, t: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i, t: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, t: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((k, 1), jnp.float32),    # running draft minima
            pltpu.VMEM((k, 1), jnp.int32),      # running draft argmins
            pltpu.VMEM((1, 1), jnp.float32),    # running target min
            pltpu.VMEM((1, 1), jnp.int32),      # running target argmin
        ],
        interpret=(mode == "interpret"),
    )(log_s, log_p, log_q, active_f)
    return x, y[:, 0]
