"""Pallas TPU kernel for the GLS race (the paper's verification hot op).

TPU adaptation (DESIGN.md §3): the vocabulary axis (up to 256k) is tiled
into VMEM-sized blocks (lane-aligned, multiples of 128); the
``log S - log p`` transform is fused with a running (min, argmin)
reduction held in VMEM scratch, so the (K, N) race table never makes a
second HBM round trip.  The K-way min for the target rides the sublane
dimension of the same pass.

Grid: (B, N // TILE_N); each program reduces one vocab tile for one batch
row.  Scratch carries the running draft minima (K,) and the target
minimum (scalar) across the vocab-tile loop (sequential minor grid axis).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TILE_N = 2048


def _kernel(log_s_ref, log_p_ref, log_q_ref, active_ref,
            x_ref, y_ref,
            dmin_ref, dargs_ref, tmin_ref, targ_ref,
            *, tile_n: int, n_tiles: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        dmin_ref[...] = jnp.full_like(dmin_ref, jnp.inf)
        dargs_ref[...] = jnp.zeros_like(dargs_ref)
        tmin_ref[...] = jnp.full_like(tmin_ref, jnp.inf)
        targ_ref[...] = jnp.zeros_like(targ_ref)

    log_s = log_s_ref[0]          # (K, TILE_N)
    log_p = log_p_ref[0]
    log_q = log_q_ref[0]
    active = active_ref[0]        # (K, 1) f32 mask (1=active)

    # --- draft races: per-k argmin of log_s - log_p ---
    dscore = log_s - log_p
    dscore = jnp.where(log_p > -jnp.inf, dscore, jnp.inf)
    tile_dmin = jnp.min(dscore, axis=1)                      # (K,)
    tile_darg = jnp.argmin(dscore, axis=1).astype(jnp.int32)
    tile_didx = t * tile_n + tile_darg
    better = tile_dmin < dmin_ref[:, 0]
    dmin_ref[:, 0] = jnp.where(better, tile_dmin, dmin_ref[:, 0])
    dargs_ref[:, 0] = jnp.where(better, tile_didx, dargs_ref[:, 0])

    # --- target race: argmin over (k, n) of log_s - log_q, active only ---
    tscore = log_s - log_q
    tscore = jnp.where(log_q > -jnp.inf, tscore, jnp.inf)
    tscore = jnp.where(active > 0, tscore, jnp.inf)
    col_min = jnp.min(tscore, axis=0)                        # (TILE_N,)
    tile_tmin = jnp.min(col_min)
    tile_targ = t * tile_n + jnp.argmin(col_min).astype(jnp.int32)
    tbetter = tile_tmin < tmin_ref[0, 0]
    tmin_ref[0, 0] = jnp.where(tbetter, tile_tmin, tmin_ref[0, 0])
    targ_ref[0, 0] = jnp.where(tbetter, tile_targ, targ_ref[0, 0])

    @pl.when(t == n_tiles - 1)
    def _emit():
        x_ref[0, :] = dargs_ref[:, 0]
        y_ref[0, 0] = targ_ref[0, 0]


def _row_kernel(log_s_ref, log_q_ref,
                rmin_out_ref, rarg_out_ref,
                rmin_ref, rarg_ref,
                *, tile_n: int, n_tiles: int):
    """Per-row (min, argmin) of the race table ``log_s - log_q``.

    The target side of Algorithm 2 needs per-(step, draft) row statistics
    — the evolving ``active`` mask is applied OUTSIDE, on (L+1, K)
    scalars — so one batched pass over (B=L+1, K, N) serves the whole
    verification block (DESIGN.md §3)."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        rmin_ref[...] = jnp.full_like(rmin_ref, jnp.inf)
        rarg_ref[...] = jnp.zeros_like(rarg_ref)

    log_s = log_s_ref[0]          # (K, TILE_N)
    log_q = log_q_ref[0]

    score = log_s - log_q
    score = jnp.where(log_q > -jnp.inf, score, jnp.inf)
    tile_min = jnp.min(score, axis=1)                        # (K,)
    tile_arg = jnp.argmin(score, axis=1).astype(jnp.int32)
    tile_idx = t * tile_n + tile_arg
    better = tile_min < rmin_ref[:, 0]
    rmin_ref[:, 0] = jnp.where(better, tile_min, rmin_ref[:, 0])
    rarg_ref[:, 0] = jnp.where(better, tile_idx, rarg_ref[:, 0])

    @pl.when(t == n_tiles - 1)
    def _emit():
        rmin_out_ref[0, :] = rmin_ref[:, 0]
        rarg_out_ref[0, :] = rarg_ref[:, 0]


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def gls_row_race(log_s: jax.Array, log_q: jax.Array, *,
                 tile_n: int = DEFAULT_TILE_N, interpret: bool = True):
    """Per-row GLS race statistics.  log_s/log_q: (B, K, N) f32.

    Returns (rmin (B, K) f32, rarg (B, K) i32): the minimum race time and
    its vocab index for every (batch, draft) row.  ``-inf`` in log_q
    marks zero-probability symbols (never win).  Ties break toward the
    lower vocab index, matching ``jnp.argmin``.
    """
    b, k, n = log_s.shape
    if n % tile_n:
        pad = tile_n - n % tile_n
        log_s = jnp.pad(log_s, ((0, 0), (0, 0), (0, pad)),
                        constant_values=0.0)
        log_q = jnp.pad(log_q, ((0, 0), (0, 0), (0, pad)),
                        constant_values=jnp.float32(-jnp.inf))
        n = n + pad
    n_tiles = n // tile_n

    kernel = functools.partial(_row_kernel, tile_n=tile_n, n_tiles=n_tiles)
    rmin, rarg = pl.pallas_call(
        kernel,
        grid=(b, n_tiles),
        in_specs=[
            pl.BlockSpec((1, k, tile_n), lambda i, t: (i, 0, t)),
            pl.BlockSpec((1, k, tile_n), lambda i, t: (i, 0, t)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i, t: (i, 0)),
            pl.BlockSpec((1, k), lambda i, t: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((k, 1), jnp.float32),    # running row minima
            pltpu.VMEM((k, 1), jnp.int32),      # running row argmins
        ],
        interpret=interpret,
    )(log_s, log_q)
    return rmin, rarg


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def gls_race(log_s: jax.Array, log_p: jax.Array, log_q: jax.Array,
             active: jax.Array, *, tile_n: int = DEFAULT_TILE_N,
             interpret: bool = True):
    """log_s/log_p/log_q: (B, K, N) f32; active: (B, K) bool.

    Returns (x (B, K) i32, y (B,) i32).  ``interpret=True`` runs the
    kernel body on CPU (this container); on TPU pass interpret=False.
    """
    b, k, n = log_s.shape
    if n % tile_n:
        pad = tile_n - n % tile_n
        neg = jnp.float32(-jnp.inf)
        log_s = jnp.pad(log_s, ((0, 0), (0, 0), (0, pad)),
                        constant_values=0.0)
        log_p = jnp.pad(log_p, ((0, 0), (0, 0), (0, pad)),
                        constant_values=neg)
        log_q = jnp.pad(log_q, ((0, 0), (0, 0), (0, pad)),
                        constant_values=neg)
        n = n + pad
    n_tiles = n // tile_n
    active_f = active.astype(jnp.float32)[..., None]  # (B, K, 1)

    kernel = functools.partial(_kernel, tile_n=tile_n, n_tiles=n_tiles)
    x, y = pl.pallas_call(
        kernel,
        grid=(b, n_tiles),
        in_specs=[
            pl.BlockSpec((1, k, tile_n), lambda i, t: (i, 0, t)),
            pl.BlockSpec((1, k, tile_n), lambda i, t: (i, 0, t)),
            pl.BlockSpec((1, k, tile_n), lambda i, t: (i, 0, t)),
            pl.BlockSpec((1, k, 1), lambda i, t: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i, t: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, t: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((k, 1), jnp.float32),    # running draft minima
            pltpu.VMEM((k, 1), jnp.int32),      # running draft argmins
            pltpu.VMEM((1, 1), jnp.float32),    # running target min
            pltpu.VMEM((1, 1), jnp.int32),      # running target argmin
        ],
        interpret=interpret,
    )(log_s, log_p, log_q, active_f)
    return x, y[:, 0]
