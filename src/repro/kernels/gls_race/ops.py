"""jit'd public wrappers for the GLS race kernels with jnp fallbacks."""

from __future__ import annotations

import jax

from repro.kernels.gls_race.kernel import gls_race, gls_row_race
from repro.kernels.gls_race.ref import gls_race_ref, gls_row_race_ref


def gls_race_op(log_s, log_p, log_q, active, *, use_kernel: bool = True,
                interpret: bool = True):
    if use_kernel:
        return gls_race(log_s, log_p, log_q, active, interpret=interpret)
    return jax.jit(gls_race_ref)(log_s, log_p, log_q, active)


def gls_row_race_op(log_s, log_q, *, use_kernel: bool = True,
                    interpret: bool = True):
    if use_kernel:
        return gls_row_race(log_s, log_q, interpret=interpret)
    return jax.jit(gls_row_race_ref)(log_s, log_q)
