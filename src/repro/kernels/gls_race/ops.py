"""jit'd public wrappers for the GLS race kernels with jnp fallbacks.

``dispatch_counts`` is trace-time dispatch accounting: each op wrapper
bumps its counter while its body is being traced into a program, so the
count equals the number of race dispatches EMBEDDED in each compiled
program (a program traced once and executed many times performs exactly
that many kernel dispatches per execution).  tests/test_compression.py
uses it to pin the Wyner–Ziv pipeline's dispatch structure, and
benchmarks/bench_serving_backends.py records per-strategy counts so
dispatch-count artifacts (the gls-vs-spectr K=2 gap) are visible in the
bench JSON instead of inferred.

Counter keys name the REQUESTED route ("..._pallas" vs "..._xla"): the
kernel layer may still resolve a pallas-route call to its bit-identical
jnp reference where the backend lacks Pallas support (``interpret=None``
autodetection, DESIGN.md §11) — execution mode is ``resolve_race_mode``'s
business, dispatch accounting is about program structure.
"""

from __future__ import annotations

import collections

import jax

from repro.kernels.gls_race.kernel import (
    gls_binned_race,
    gls_race,
    gls_row_race,
    has_compiled_pallas,
    resolve_race_mode,
)
from repro.kernels.gls_race.ref import (
    gls_binned_race_ref,
    gls_race_ref,
    gls_row_race_ref,
)

__all__ = [
    "dispatch_counts",
    "reset_dispatch_counts",
    "gls_race_op",
    "gls_row_race_op",
    "gls_binned_race_op",
    "has_compiled_pallas",
    "resolve_race_mode",
]

dispatch_counts: collections.Counter = collections.Counter()


def reset_dispatch_counts() -> None:
    dispatch_counts.clear()


def gls_race_op(log_s, log_p, log_q, active, *, use_kernel: bool = True,
                interpret: bool | None = None):
    dispatch_counts["race_" + ("pallas" if use_kernel else "xla")] += 1
    if use_kernel:
        return gls_race(log_s, log_p, log_q, active, interpret=interpret)
    return jax.jit(gls_race_ref)(log_s, log_p, log_q, active)


def gls_row_race_op(log_s, log_q, *, use_kernel: bool = True,
                    interpret: bool | None = None):
    dispatch_counts["row_race_" + ("pallas" if use_kernel else "xla")] += 1
    if use_kernel:
        return gls_row_race(log_s, log_q, interpret=interpret)
    return jax.jit(gls_row_race_ref)(log_s, log_q)


def gls_binned_race_op(log_s, log_q, bins, *, l_max: int,
                       use_kernel: bool = True,
                       interpret: bool | None = None,
                       tile_n: int = None):
    """Bin-masked race statistics; ``use_kernel`` routes to the Pallas
    kernel, else the jnp oracle (bit-identical outputs either way).
    ``tile_n`` caps the kernel's atom tile (None = kernel default)."""
    dispatch_counts["binned_race_" + ("pallas" if use_kernel else "xla")] += 1
    if use_kernel:
        return gls_binned_race(log_s, log_q, bins, l_max=l_max,
                               interpret=interpret, tile_n=tile_n)
    return gls_binned_race_ref(log_s, log_q, bins, l_max=l_max)
