"""jit'd public wrapper for the GLS race kernel with a jnp fallback."""

from __future__ import annotations

import jax

from repro.kernels.gls_race.kernel import gls_race
from repro.kernels.gls_race.ref import gls_race_ref


def gls_race_op(log_s, log_p, log_q, active, *, use_kernel: bool = True,
                interpret: bool = True):
    if use_kernel:
        return gls_race(log_s, log_p, log_q, active, interpret=interpret)
    return jax.jit(gls_race_ref)(log_s, log_p, log_q, active)
