"""Pallas TPU flash-attention (prefill) kernel with GQA, causal and
sliding-window masking, and per-row offsets for cache-arena prefill.

Tiling: grid (B, H, S/TQ, T/TK); online-softmax carry (m, l, acc) lives in
VMEM scratch across the sequential KV-tile axis.  Block shapes keep the
MXU busy (TQ x D and TK x D tiles, lane dim = head_dim, sublane = seq) and
the working set ~ (TQ + 2*TK) * D * 4B well under VMEM.  KV heads are
indexed as h // group so grouped query heads reuse the same KV tiles
(no repeated-KV materialization in HBM).

Arena prefill (DESIGN.md §9): each batch row may sit at its own decode
position, so the kernel takes per-row ``q_offset`` (position of the
row's first query) and ``kv_len`` (valid KV prefix length) as SMEM
scalars — the same per-row masking contract as the dense
``layers.attention`` path and the decode-attention kernel.  Rows whose
queries are entirely masked (bucket padding) emit zeros, not NaN.

int8 KV arenas (DESIGN.md §11) pass per-KV-vector scales ``k_scale`` /
``v_scale`` (B, Hkv, T, 1), dequantized in-kernel tile by tile so the
HBM stream stays int8.  Execution mode follows ``resolve_pallas_mode``:
``interpret=None`` compiles on TPU/GPU and falls back to the
bit-for-bit jnp reference elsewhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.pallas_mode import resolve_pallas_mode

DEFAULT_TQ = 256
DEFAULT_TK = 256


def _kernel(q_off_ref, kv_len_ref, q_ref, k_ref, v_ref, *refs,
            tq: int, tk: int, n_kv: int,
            causal: bool, window: int, t_real: int, quant: bool):
    if quant:
        k_s_ref, v_s_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)      # (TQ, D)
    k = k_ref[0, 0].astype(jnp.float32)      # (TK, D)
    v = v_ref[0, 0].astype(jnp.float32)
    if quant:
        k = k * k_s_ref[0, 0]                # (TK, 1) broadcasts over D
        v = v * v_s_ref[0, 0]
    d = q.shape[-1]
    q_off = q_off_ref[0]
    kv_len = kv_len_ref[0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) / jnp.sqrt(
        jnp.float32(d))
    q_pos = q_off + iq * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
    k_pos = ik * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
    mask = (k_pos < t_real) & (k_pos < kv_len)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, -jnp.inf)

    # Masked-row contract shared with ref.py's masked_softmax: a row
    # whose running max never leaves -inf (fully masked so far) pins the
    # exp argument at -inf via m_safe, so its weights are exactly 0.0 —
    # never a NaN that needs scrubbing after the fact.
    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[:, 0] = m_new

    @pl.when(ik == n_kv - 1)
    def _emit():
        # Fully-masked query rows (bucket padding, kv_len == 0) have
        # l == 0; the 1e-30 floor turns them into zeros rather than
        # NaN — matching ref.py's masked_softmax denominator floor
        # bitwise.  Rows with any valid key have l >= 1 (the max entry
        # contributes exp(0) = 1), so the floor is inert there.
        denom = jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "tq", "tk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_offset: jax.Array = None, kv_len: jax.Array = None,
                    k_scale: jax.Array = None, v_scale: jax.Array = None, *,
                    causal: bool = True, window: int = 0,
                    tq: int = DEFAULT_TQ, tk: int = DEFAULT_TK,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, Hkv, T, D) -> (B, H, S, D).

    ``q_offset``/``kv_len`` are optional (B,) i32 per-row masks: row b's
    queries sit at positions ``q_offset[b] + arange(S)`` and attend only
    keys below ``kv_len[b]`` (defaults: offset 0, full T).
    ``k_scale``/``v_scale`` (B, Hkv, T, 1), both or neither: per-KV-vector
    dequant scales for int8 k/v, applied in-kernel tile by tile.
    """
    assert (k_scale is None) == (v_scale is None)
    quant = k_scale is not None
    mode = resolve_pallas_mode(interpret)
    if mode == "fallback":
        return flash_attention_ref(q, k, v, q_offset, kv_len, k_scale,
                                   v_scale, causal=causal, window=window)
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    tq = min(tq, s)
    tk = min(tk, t)
    if s % tq:
        qpad = tq - s % tq
        q = jnp.pad(q, ((0, 0), (0, 0), (0, qpad), (0, 0)))
    if t % tk:
        kpad = tk - t % tk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, kpad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, kpad), (0, 0)))
        if quant:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, 0), (0, kpad), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, 0), (0, kpad), (0, 0)))
    s_pad, t_pad = q.shape[2], k.shape[2]
    n_q, n_kv = s_pad // tq, t_pad // tk
    if q_offset is None:
        q_offset = jnp.zeros((b,), jnp.int32)
    if kv_len is None:
        kv_len = jnp.full((b,), t, jnp.int32)
    q_offset = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (b,))
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,))

    kernel = functools.partial(_kernel, tq=tq, tk=tk, n_kv=n_kv,
                               causal=causal, window=window, t_real=t,
                               quant=quant)
    in_specs = [
        pl.BlockSpec((1,), lambda b_, h_, iq, ik: (b_,),
                     memory_space=pltpu.SMEM),
        pl.BlockSpec((1,), lambda b_, h_, iq, ik: (b_,),
                     memory_space=pltpu.SMEM),
        pl.BlockSpec((1, 1, tq, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        pl.BlockSpec((1, 1, tk, d),
                     lambda b_, h_, iq, ik, g=g: (b_, h_ // g, ik, 0)),
        pl.BlockSpec((1, 1, tk, d),
                     lambda b_, h_, iq, ik, g=g: (b_, h_ // g, ik, 0)),
    ]
    operands = [q_offset, kv_len, q, k, v]
    if quant:
        in_specs += [
            pl.BlockSpec((1, 1, tk, 1),
                         lambda b_, h_, iq, ik, g=g: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, tk, 1),
                         lambda b_, h_, iq, ik, g=g: (b_, h_ // g, ik, 0)),
        ]
        operands += [k_scale, v_scale]
    out = pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_kv),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, tq, d),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, 1), jnp.float32),   # running max m
            pltpu.VMEM((tq, 1), jnp.float32),   # running denom l
            pltpu.VMEM((tq, d), jnp.float32),   # running numerator acc
        ],
        interpret=(mode == "interpret"),
    )(*operands)
    return out[:, :, :s]
