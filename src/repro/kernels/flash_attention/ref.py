"""Pure-jnp oracle for the flash-attention prefill kernel: exact GQA
attention with causal, sliding-window, and per-row offset masking.
Accepts optional per-KV-vector dequant scales so int8 KV arenas
(DESIGN.md §11) share one reference."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        q_offset: Optional[jax.Array] = None,
                        kv_len: Optional[jax.Array] = None,
                        k_scale: Optional[jax.Array] = None,
                        v_scale: Optional[jax.Array] = None, *,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, Hkv, T, D).  f32 math, returns q.dtype.

    ``q_offset``/``kv_len``: optional (B,) i32 per-row masks mirroring
    the kernel's arena-prefill contract (defaults: offset 0, full T).
    ``k_scale``/``v_scale`` (B, Hkv, T, 1), both or neither: dequant
    scales for int8 k/v — ``k_f32 = k * k_scale`` before the math."""
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale
        vf = vf * v_scale
    qr = q.reshape(b, hkv, g, s, d).astype(jnp.float32)
    scores = jnp.einsum("bhgsd,bhtd->bhgst", qr, kf)
    scores = scores / jnp.sqrt(d)
    q_off = (jnp.zeros((b,), jnp.int32) if q_offset is None
             else jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (b,)))
    kvl = (jnp.full((b,), t, jnp.int32) if kv_len is None
           else jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,)))
    q_pos = q_off[:, None] + jnp.arange(s)                  # (B, S)
    k_pos = jnp.arange(t)
    mask = k_pos[None, None, :] < kvl[:, None, None]        # (B, S, T)
    if causal:
        mask &= k_pos[None, None, :] <= q_pos[:, :, None]
    if window:
        mask &= k_pos[None, None, :] > q_pos[:, :, None] - window
    scores = jnp.where(mask[:, None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)
    out = jnp.einsum("bhgst,bhtd->bhgsd", w, vf)
    return out.reshape(b, h, s, d).astype(q.dtype)
