"""Pure-jnp oracle for the flash-attention prefill kernel: exact GQA
attention with causal and sliding-window masking."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, Hkv, T, D).  f32 math, returns q.dtype."""
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    qr = q.reshape(b, hkv, g, s, d).astype(jnp.float32)
    scores = jnp.einsum("bhgsd,bhtd->bhgst", qr, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(d)
    q_pos = jnp.arange(s)
    k_pos = jnp.arange(t)
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)
    out = jnp.einsum("bhgst,bhtd->bhgsd", w, v.astype(jnp.float32))
    return out.reshape(b, h, s, d).astype(q.dtype)
