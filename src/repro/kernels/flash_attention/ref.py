"""Pure-jnp oracle for the flash-attention prefill kernel: exact GQA
attention with causal, sliding-window, and per-row offset masking.
Accepts optional per-KV-vector dequant scales so int8 KV arenas
(DESIGN.md §11) share one reference."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def masked_softmax(scores: jax.Array, mask: jax.Array) -> jax.Array:
    """Softmax over the last axis with ``mask`` selecting valid entries,
    under the repo-wide masked-row contract shared with the Pallas
    kernel (kernel.py ``_kernel``):

      * rows with >= 1 valid entry: bitwise identical to
        ``jax.nn.softmax`` over the ``-inf``-masked scores — the row
        max is finite, so the ``m_safe`` substitution is a no-op, the
        max entry contributes ``exp(0) = 1`` so the denominator is
        >= 1 and the ``1e-30`` floor is inert, and masked entries are
        ``exp(-inf - m) = 0.0`` exactly;
      * fully-masked rows: all-zero weights (the kernel's running max
        never leaves its ``-inf`` init, so ``m_safe`` pins the exps'
        argument at ``-inf`` and every weight underflows to exactly
        0.0), instead of softmax's 0/0 = NaN.

    The old reference computed NaN weights first and scrubbed them with
    ``isnan`` after the fact; that disagreed with the kernel whenever a
    score was NaN for any OTHER reason (poisoned KV), silently zeroing
    corruption the kernel would propagate.  Producing zeros directly
    keeps the two paths bitwise aligned on every masked-row shape."""
    neg = jnp.where(mask, scores, -jnp.inf)
    m = jnp.max(neg, axis=-1, keepdims=True)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(mask, jnp.exp(neg - m_safe), 0.0)
    return p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        q_offset: Optional[jax.Array] = None,
                        kv_len: Optional[jax.Array] = None,
                        k_scale: Optional[jax.Array] = None,
                        v_scale: Optional[jax.Array] = None, *,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, Hkv, T, D).  f32 math, returns q.dtype.

    ``q_offset``/``kv_len``: optional (B,) i32 per-row masks mirroring
    the kernel's arena-prefill contract (defaults: offset 0, full T).
    ``k_scale``/``v_scale`` (B, Hkv, T, 1), both or neither: dequant
    scales for int8 k/v — ``k_f32 = k * k_scale`` before the math."""
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale
        vf = vf * v_scale
    qr = q.reshape(b, hkv, g, s, d).astype(jnp.float32)
    scores = jnp.einsum("bhgsd,bhtd->bhgst", qr, kf)
    scores = scores / jnp.sqrt(d)
    q_off = (jnp.zeros((b,), jnp.int32) if q_offset is None
             else jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (b,)))
    kvl = (jnp.full((b,), t, jnp.int32) if kv_len is None
           else jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,)))
    q_pos = q_off[:, None] + jnp.arange(s)                  # (B, S)
    k_pos = jnp.arange(t)
    mask = k_pos[None, None, :] < kvl[:, None, None]        # (B, S, T)
    if causal:
        mask &= k_pos[None, None, :] <= q_pos[:, :, None]
    if window:
        mask &= k_pos[None, None, :] > q_pos[:, :, None] - window
    w = masked_softmax(scores, mask[:, None, None])
    out = jnp.einsum("bhgst,bhtd->bhgsd", w, vf)
    return out.reshape(b, h, s, d).astype(q.dtype)
