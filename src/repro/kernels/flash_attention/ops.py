"""jit'd public wrapper for flash attention with a jnp fallback.

``interpret=None`` autodetects per ``resolve_pallas_mode`` (compiled on
TPU/GPU, jnp reference elsewhere); ``k_scale``/``v_scale`` pass through
for int8 KV arenas."""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref


def flash_attention_op(q, k, v, q_offset=None, kv_len=None, k_scale=None,
                       v_scale=None, *, causal=True, window=0,
                       use_kernel: bool = True,
                       interpret: bool | None = None):
    if use_kernel:
        return flash_attention(q, k, v, q_offset, kv_len, k_scale, v_scale,
                               causal=causal, window=window,
                               interpret=interpret)
    fn = functools.partial(flash_attention_ref, causal=causal, window=window)
    return jax.jit(fn)(q, k, v, q_offset, kv_len, k_scale, v_scale)


def flash_attention_paged_op(q, k_pages, v_pages, table, q_offset=None,
                             kv_len=None, k_scale_pages=None,
                             v_scale_pages=None, *, buf_len: int,
                             causal=True, window=0,
                             use_kernel: bool = True,
                             interpret: bool | None = None):
    """Flash attention over a paged KV pool (DESIGN.md §12).

    ``k_pages``/``v_pages``: (P, Hkv, page, D) physical pools;
    ``table``: (B, n_lp) int32 page table (0 = unmapped);
    ``buf_len``: static contiguous view length.  The page table is
    resolved by a reference gather into a (B, Hkv, buf_len, D) view and
    the math is the contiguous op's, bit-identically — a TPU kernel
    would instead resolve the table in the BlockSpec index map
    (``kernels.paged`` docstring)."""
    from repro.kernels.paged import gather_kv_pages
    k = gather_kv_pages(k_pages, table, buf_len)
    v = gather_kv_pages(v_pages, table, buf_len)
    ks = vs = None
    if k_scale_pages is not None:
        ks = gather_kv_pages(k_scale_pages, table, buf_len)
        vs = gather_kv_pages(v_scale_pages, table, buf_len)
    return flash_attention_op(q, k, v, q_offset, kv_len, ks, vs,
                              causal=causal, window=window,
                              use_kernel=use_kernel, interpret=interpret)
