"""jit'd public wrapper for flash attention with a jnp fallback.

``interpret=None`` autodetects per ``resolve_pallas_mode`` (compiled on
TPU/GPU, jnp reference elsewhere); ``k_scale``/``v_scale`` pass through
for int8 KV arenas."""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref


def flash_attention_op(q, k, v, q_offset=None, kv_len=None, k_scale=None,
                       v_scale=None, *, causal=True, window=0,
                       use_kernel: bool = True,
                       interpret: bool | None = None):
    if use_kernel:
        return flash_attention(q, k, v, q_offset, kv_len, k_scale, v_scale,
                               causal=causal, window=window,
                               interpret=interpret)
    fn = functools.partial(flash_attention_ref, causal=causal, window=window)
    return jax.jit(fn)(q, k, v, q_offset, kv_len, k_scale, v_scale)
