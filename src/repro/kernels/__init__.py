"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel directory holds kernel.py (pl.pallas_call + BlockSpec),
ops.py (jit'd wrapper with jnp fallback) and ref.py (pure-jnp oracle).
All are validated in interpret=True mode on CPU; on TPU pass
interpret=False.
"""

from repro.kernels.decode_attention.ops import decode_attention_op
from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.gls_race.ops import gls_race_op, gls_row_race_op
from repro.kernels.ssd_chunk.ops import ssd_chunk_op, ssd_chunked_kernel

__all__ = ["decode_attention_op", "flash_attention_op", "gls_race_op",
           "gls_row_race_op", "ssd_chunk_op", "ssd_chunked_kernel"]
