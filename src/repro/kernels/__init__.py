"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel directory holds kernel.py (pl.pallas_call + BlockSpec),
ops.py (jit'd wrapper with jnp fallback) and ref.py (pure-jnp oracle).

Execution mode (DESIGN.md §11.1): every entry point takes
``interpret: bool | None = None``, resolved by
``kernels.pallas_mode.resolve_pallas_mode`` — ``None`` runs COMPILED
Pallas on backends that lower it (TPU/GPU) and the bit-identical jitted
reference elsewhere; ``True`` forces interpret mode (the CPU test mode
— it executes the same kernel body that compiles on device); ``False``
forces compiled, failing loudly on unsupported backends.  Callers
should leave the default alone.
"""

from repro.kernels.decode_attention.ops import (
    decode_attention_op,
    decode_attention_paged_op,
)
from repro.kernels.flash_attention.ops import (
    flash_attention_op,
    flash_attention_paged_op,
)
from repro.kernels.gls_race.ops import gls_race_op, gls_row_race_op
from repro.kernels.paged import gather_kv_pages
from repro.kernels.ssd_chunk.ops import ssd_chunk_op, ssd_chunked_kernel

__all__ = ["decode_attention_op", "decode_attention_paged_op",
           "flash_attention_op", "flash_attention_paged_op",
           "gather_kv_pages", "gls_race_op", "gls_row_race_op",
           "ssd_chunk_op", "ssd_chunked_kernel"]
