"""Kernel-level paged-KV layout and the gather that resolves it.

DESIGN.md §12: the paged arena stores each (slot-row, layer) KV stream
as a chain of fixed-size time pages in a physical pool
``(P, Hkv, page, D)``; a device-resident page table ``(B, n_lp)`` int32
maps each batch row's logical page ``j`` to a physical page index.
Physical page 0 is a permanent all-zero page and table entry 0 means
"unmapped" — both resolve to zeros under the gather, and zeros beyond
``kv_len`` are masked to exact ``-inf`` by every attention op, so an
unmapped tail is token-invisible.

``gather_kv_pages`` is the REFERENCE resolution of that indirection:
one ``jnp.take`` over the page axis materializes a contiguous
``(B, Hkv, T, D)`` view, which then feeds the existing flash/decode
attention entry points unchanged — paged attention is bit-identical to
contiguous attention by construction, because it runs the identical
math on an identical view.  On TPU the gather never needs to
materialize: a Pallas kernel resolves the page table inside the
BlockSpec index map (each grid step's KV tile address comes from
``table[b, j]`` instead of ``j``), streaming pages HBM→VMEM directly.
That fused variant is a follow-on; this module pins its semantics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_kv_pages(pages: jax.Array, table: jax.Array,
                    t: int) -> jax.Array:
    """Resolve a page table into a contiguous KV view.

    pages: (P, Hkv, page, D) physical page pool (page 0 all-zero);
    table: (B, n_lp) int32 logical->physical map (0 = unmapped);
    t:     static view length, t <= n_lp * page.
    Returns (B, Hkv, t, D) in the pool's dtype.
    """
    b, n_lp = table.shape
    _, hkv, page, d = pages.shape
    v = jnp.take(pages, table.reshape(-1), axis=0)      # (B*n_lp, Hkv, pg, D)
    v = v.reshape(b, n_lp, hkv, page, d)
    v = jnp.swapaxes(v, 1, 2).reshape(b, hkv, n_lp * page, d)
    return v[:, :, :t]
