"""Shared execution-mode resolution for the Pallas kernel families.

Every kernel entry point takes ``interpret: bool | None`` (DESIGN.md
§11).  ``None`` autodetects: compiled Pallas where the backend lowers it
(TPU/GPU), the kernel's bit-for-bit-documented jnp reference elsewhere
(CPU) — so callers never hard-code the execution mode.  ``True`` forces
the Pallas interpreter (the kernel BODY runs on any backend — what the
kernel-vs-ref tests exercise); ``False`` forces compiled lowering.
"""

from __future__ import annotations

import jax

# Backends with a Pallas compilation path; everywhere else
# ``interpret=None`` engages the jnp fallback.
_COMPILED_BACKENDS = ("tpu", "gpu")


def has_compiled_pallas() -> bool:
    """True where ``pallas_call`` has a real lowering (TPU/GPU)."""
    return jax.default_backend() in _COMPILED_BACKENDS


def resolve_pallas_mode(interpret: bool | None = None) -> str:
    """Resolve the tri-state ``interpret`` flag to an execution mode:
    "compiled" | "interpret" | "fallback" (see module doc).  Exposed so
    layered callers (the WZ pipeline, benches) can make structure
    decisions from the same resolution the kernels use."""
    if interpret is None:
        return "compiled" if has_compiled_pallas() else "fallback"
    return "interpret" if interpret else "compiled"
