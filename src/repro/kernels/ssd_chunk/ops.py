"""jit'd wrapper for the SSD intra-chunk kernel with a jnp fallback, plus
a full chunked-SSD entry point (kernel intra + jnp inter-chunk scan)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_chunk.kernel import ssd_chunk
from repro.kernels.ssd_chunk.ref import ssd_chunk_ref


def ssd_chunk_op(x, dt, a, b_in, c_in, *, use_kernel: bool = True,
                 interpret: bool = True):
    if use_kernel:
        return ssd_chunk(x, dt, a, b_in, c_in, interpret=interpret)
    return jax.jit(ssd_chunk_ref)(x, dt, a, b_in, c_in)


def ssd_chunked_kernel(x, dt, a, b_in, c_in, chunk: int, h0=None, *,
                       interpret: bool = True):
    """Drop-in twin of models.mamba2.ssd_chunked with the intra-chunk work
    on the Pallas kernel.  x: (B, S, H, P); see mamba2.ssd_chunked."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    q = chunk
    if s % q:
        pad = q - s % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    s_pad = x.shape[1]
    nc = s_pad // q
    xs = x.reshape(bsz, nc, q, h, p)
    dts = dt.reshape(bsz, nc, q, h)
    bs = b_in.reshape(bsz, nc, q, n)
    cs = c_in.reshape(bsz, nc, q, n)

    y_intra, states, total = ssd_chunk(xs, dts, a, bs, cs,
                                       interpret=interpret)

    def step(h_prev, xs_c):
        tot_c, st_c = xs_c
        h_in = h_prev
        h_out = h_prev * jnp.exp(tot_c)[:, :, None, None] + st_c
        return h_out, h_in

    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    h_final, h_ins = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (total.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    h_ins = h_ins.transpose(1, 0, 2, 3, 4)

    cum = jnp.cumsum(dts * a[None, None, None, :], axis=2)
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp",
                         cs.astype(jnp.float32), h_ins, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(bsz, s_pad, h, p)[:, :s]
    return y.astype(x.dtype), h_final
