"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk computation.

Per grid cell (batch, chunk, head) the kernel holds one chunk's tiles in
VMEM — x (Q, P), dt (Q,), B/C (Q, N) — and runs three MXU matmuls:

  cb      = C @ B^T                       (Q x N) x (N x Q)  -> (Q, Q)
  y_intra = (cb ⊙ L_decay) @ (x·dt)       (Q x Q) x (Q x P)  -> (Q, P)
  state   = (B ⊙ rem)^T @ (x·dt)          (N x Q) x (Q x P)  -> (N, P)

with the decay matrix L built from the in-chunk cumulative log-decays
(double-where masked so no inf leaks).  Q, N, P are all 64-256 —
MXU-aligned tiles, working set ≈ (2QN + QP + Q² + NP)·4B « VMEM.  The
O(seq) inter-chunk recurrence stays in jnp (lax.scan over chunk
boundaries), exactly as in the pure-jnp model path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, tot_ref):
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)    # (Q, P)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)     # (Q,)
    a = a_ref[0]                                    # scalar
    b = b_ref[0, 0].astype(jnp.float32)             # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)             # (Q, N)
    q = x.shape[0]

    la = dt * a
    cum = jnp.cumsum(la)
    total = cum[-1]

    li = cum[:, None]
    lj = cum[None, :]
    mask = li >= lj  # lower-triangular in time (cum is non-increasing-ish)
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    diff = jnp.where(tri, li - lj, 0.0)
    decay = jnp.where(tri, jnp.exp(diff), 0.0)

    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)   # (Q, Q)
    xdt = x * dt[:, None]                                      # (Q, P)
    y = jnp.dot(cb * decay, xdt, preferred_element_type=jnp.float32)

    rem = jnp.exp(total - cum)                                 # (Q,)
    state = jnp.dot((b * rem[:, None]).T, xdt,
                    preferred_element_type=jnp.float32)        # (N, P)

    y_ref[0, 0, :, 0, :] = y
    st_ref[0, 0, 0] = state.T                                  # (P, N)
    tot_ref[0, 0, 0] = total


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(x, dt, a, b_in, c_in, *, interpret: bool = True):
    """x: (B, NC, Q, H, P); dt: (B, NC, Q, H) f32; a: (H,) f32;
    b_in/c_in: (B, NC, Q, N).  Returns (y_intra, states, total) matching
    ref.ssd_chunk_ref."""
    bsz, nc, q, h, p = x.shape
    n = b_in.shape[-1]
    out = pl.pallas_call(
        _kernel,
        grid=(bsz, nc, h),
        in_specs=[
            pl.BlockSpec((1, 1, q, 1, p), lambda b, c, hh: (b, c, 0, hh, 0)),
            pl.BlockSpec((1, 1, q, 1), lambda b, c, hh: (b, c, 0, hh)),
            pl.BlockSpec((1,), lambda b, c, hh: (hh,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, q, n), lambda b, c, hh: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda b, c, hh: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, 1, p), lambda b, c, hh: (b, c, 0, hh, 0)),
            pl.BlockSpec((1, 1, 1, p, n), lambda b, c, hh: (b, c, hh, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, c, hh: (b, c, hh),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nc, q, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, nc, h, p, n), jnp.float32),
            jax.ShapeDtypeStruct((bsz, nc, h), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a, b_in, c_in)
    return out
