"""Pure-jnp oracle for the SSD intra-chunk kernel: for each
(batch, chunk, head) tile compute the decay-masked quadratic output and
the chunk summary state (Mamba-2 / SSD, arXiv:2405.21060)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunk_ref(x, dt, a, b_in, c_in):
    """x: (B, NC, Q, H, P); dt: (B, NC, Q, H) f32 (already softplus'd);
    a: (H,) f32 negative; b_in/c_in: (B, NC, Q, N).

    Returns (y_intra (B,NC,Q,H,P) f32, states (B,NC,H,P,N) f32,
             total (B,NC,H) f32 log-decay across each chunk).
    """
    q = x.shape[2]
    la = dt * a[None, None, None, :]
    cum = jnp.cumsum(la, axis=2)
    total = cum[:, :, -1]
    li = cum[:, :, :, None, :]
    lj = cum[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    diff = jnp.where(mask, li - lj, 0.0)
    decay = jnp.where(mask, jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", c_in.astype(jnp.float32),
                    b_in.astype(jnp.float32))
    w = cb[..., None] * decay
    xdt = x.astype(jnp.float32) * dt[..., None]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xdt)
    rem = jnp.exp(total[:, :, None, :] - cum)
    states = jnp.einsum("bcjh,bcjn,bcjhp->bchpn",
                        rem, b_in.astype(jnp.float32), xdt)
    return y_intra, states, total
