"""Pallas TPU decode-attention kernel: one query token per head against a
(possibly ring-buffered) KV cache.

Decode attention is memory-bound — the whole KV cache streams through
once per step — so the kernel's job is to keep that stream dense: grid
(B, Hkv, T/TK) walks KV tiles sequentially while the G grouped query
heads ride the sublane dimension, with the online-softmax carry
(m, l, acc) in VMEM.  kv_len masks the invalid tail (ring caches pass
min(pos+1, T)).

int8 KV arenas (DESIGN.md §11) pass per-KV-vector scales ``k_scale`` /
``v_scale`` (B, Hkv, T, 1): the kernel dequantizes IN the tile loop —
``k_f32 = k_int8 * scale`` right after the tile lands in VMEM — so what
streams from HBM is the 4x-smaller int8 arena plus one f32 scale per
vector, never a dequantized copy.

Execution mode follows ``resolve_pallas_mode``: ``interpret=None``
compiles on TPU/GPU and falls back to the bit-for-bit jnp reference
elsewhere; ``True`` forces the interpreter (kernel-body tests)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.pallas_mode import resolve_pallas_mode

DEFAULT_TK = 512


def _kernel(kv_len_ref, q_ref, k_ref, v_ref, *refs,
            tk: int, n_kv: int, quant: bool):
    if quant:
        k_s_ref, v_s_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)        # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)        # (TK, D)
    v = v_ref[0, 0].astype(jnp.float32)
    if quant:
        k = k * k_s_ref[0, 0]                  # (TK, 1) broadcasts over D
        v = v * v_s_ref[0, 0]
    d = q.shape[-1]
    g = q.shape[0]
    kv_len = kv_len_ref[0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) / jnp.sqrt(
        jnp.float32(d))                        # (G, TK)
    k_pos = ik * tk + jax.lax.broadcasted_iota(jnp.int32, (g, tk), 1)
    mask = k_pos < kv_len
    s = jnp.where(mask, s, -jnp.inf)

    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.where(mask, jnp.exp(s - m_safe[:, None]), 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[:, 0] = m_new

    @pl.when(ik == n_kv - 1)
    def _emit():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tk", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array, k_scale: jax.Array = None,
                     v_scale: jax.Array = None, *, tk: int = DEFAULT_TK,
                     interpret: bool | None = None) -> jax.Array:
    """q: (B, H, D); k/v: (B, Hkv, T, D); kv_len: (B,) -> (B, H, D).

    ``k_scale``/``v_scale`` (B, Hkv, T, 1), both or neither: per-KV-vector
    dequant scales for int8 k/v, applied in-kernel tile by tile."""
    assert (k_scale is None) == (v_scale is None)
    quant = k_scale is not None
    mode = resolve_pallas_mode(interpret)
    if mode == "fallback":
        return decode_attention_ref(q, k, v, kv_len, k_scale, v_scale)
    b, h, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    tk = min(tk, t)
    if t % tk:
        pad = tk - t % tk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        if quant:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, 0), (0, pad), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, 0), (0, pad), (0, 0)))
    t_pad = k.shape[2]
    n_kv = t_pad // tk
    # (B, Hkv, G, D) — grouped query heads per KV head.
    qg = q.reshape(b, hkv, g, d)
    kv_len = kv_len.astype(jnp.int32)

    kernel = functools.partial(_kernel, tk=tk, n_kv=n_kv, quant=quant)
    in_specs = [
        pl.BlockSpec((1,), lambda b_, h_, ik: (b_,),
                     memory_space=pltpu.SMEM),
        pl.BlockSpec((1, 1, g, d), lambda b_, h_, ik: (b_, h_, 0, 0)),
        pl.BlockSpec((1, 1, tk, d), lambda b_, h_, ik: (b_, h_, ik, 0)),
        pl.BlockSpec((1, 1, tk, d), lambda b_, h_, ik: (b_, h_, ik, 0)),
    ]
    operands = [kv_len, qg, k, v]
    if quant:
        in_specs += [
            pl.BlockSpec((1, 1, tk, 1), lambda b_, h_, ik: (b_, h_, ik, 0)),
            pl.BlockSpec((1, 1, tk, 1), lambda b_, h_, ik: (b_, h_, ik, 0)),
        ]
        operands += [k_scale, v_scale]
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv, n_kv),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h_, ik: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=(mode == "interpret"),
    )(*operands)
    return out.reshape(b, h, d)
