"""Pure-jnp oracle for single-token GQA decode attention over a KV cache
with a valid-prefix length."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: jax.Array) -> jax.Array:
    """q: (B, H, D) one query per head; k/v: (B, Hkv, T, D);
    kv_len: (B,) valid prefix length.  Returns (B, H, D)."""
    b, h, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    qr = q.reshape(b, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bhtd->bhgt", qr, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(d)
    valid = jnp.arange(t)[None, :] < kv_len[:, None]     # (B, T)
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgt,bhtd->bhgd", w, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)
