"""Pure-jnp oracle for single-token GQA decode attention over a KV cache
with a valid-prefix length.  Accepts optional per-KV-vector dequant
scales so int8 KV arenas (DESIGN.md §11) share one reference."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: jax.Array, k_scale: jax.Array = None,
                         v_scale: jax.Array = None) -> jax.Array:
    """q: (B, H, D) one query per head; k/v: (B, Hkv, T, D);
    kv_len: (B,) valid prefix length.  Returns (B, H, D).

    ``k_scale``/``v_scale`` (B, Hkv, T, 1), both or neither: dequant
    scales for int8 k/v — ``k_f32 = k * k_scale`` before the math."""
    b, h, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale
        vf = vf * v_scale
    qr = q.reshape(b, hkv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bhtd->bhgt", qr, kf)
    scores = scores / jnp.sqrt(d)
    valid = jnp.arange(t)[None, :] < kv_len[:, None]     # (B, T)
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgt,bhtd->bhgd", w, vf)
    return out.reshape(b, h, d).astype(q.dtype)
