"""jit'd public wrapper for decode attention with a jnp fallback.

``interpret=None`` autodetects per ``resolve_pallas_mode`` (compiled on
TPU/GPU, jnp reference elsewhere); ``k_scale``/``v_scale`` pass through
for int8 KV arenas."""

from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


def decode_attention_op(q, k, v, kv_len, k_scale=None, v_scale=None, *,
                        use_kernel: bool = True,
                        interpret: bool | None = None):
    if use_kernel:
        return decode_attention(q, k, v, kv_len, k_scale, v_scale,
                                interpret=interpret)
    return jax.jit(decode_attention_ref)(q, k, v, kv_len, k_scale, v_scale)


def decode_attention_paged_op(q, k_pages, v_pages, table, kv_len,
                              k_scale_pages=None, v_scale_pages=None, *,
                              buf_len: int, use_kernel: bool = True,
                              interpret: bool | None = None):
    """Decode attention over a paged KV pool (DESIGN.md §12).

    ``k_pages``/``v_pages``: (P, Hkv, page, D) physical pools;
    ``table``: (B, n_lp) int32 page table (0 = unmapped);
    ``buf_len``: static contiguous view length.  The page table is
    resolved by a reference gather into a (B, Hkv, buf_len, D) view and
    the math is the contiguous op's, bit-identically — a TPU kernel
    would instead resolve the table in the BlockSpec index map
    (``kernels.paged`` docstring)."""
    from repro.kernels.paged import gather_kv_pages
    k = gather_kv_pages(k_pages, table, buf_len)
    v = gather_kv_pages(v_pages, table, buf_len)
    ks = vs = None
    if k_scale_pages is not None:
        ks = gather_kv_pages(k_scale_pages, table, buf_len)
        vs = gather_kv_pages(v_scale_pages, table, buf_len)
    return decode_attention_op(q, k, v, kv_len, ks, vs,
                               use_kernel=use_kernel, interpret=interpret)
