"""jit'd public wrapper for decode attention with a jnp fallback.

``interpret=None`` autodetects per ``resolve_pallas_mode`` (compiled on
TPU/GPU, jnp reference elsewhere); ``k_scale``/``v_scale`` pass through
for int8 KV arenas."""

from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


def decode_attention_op(q, k, v, kv_len, k_scale=None, v_scale=None, *,
                        use_kernel: bool = True,
                        interpret: bool | None = None):
    if use_kernel:
        return decode_attention(q, k, v, kv_len, k_scale, v_scale,
                                interpret=interpret)
    return jax.jit(decode_attention_ref)(q, k, v, kv_len, k_scale, v_scale)
