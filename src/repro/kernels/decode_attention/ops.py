"""jit'd public wrapper for decode attention with a jnp fallback."""

from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


def decode_attention_op(q, k, v, kv_len, *, use_kernel: bool = True,
                        interpret: bool = True):
    if use_kernel:
        return decode_attention(q, k, v, kv_len, interpret=interpret)
    return jax.jit(decode_attention_ref)(q, k, v, kv_len)
