"""Open-loop serving benchmark: tail latency under Poisson arrivals,
FIFO on the contiguous arena vs the paged arena + v2 policy
(DESIGN.md §12).

Closed-loop benches (bench_serving_backends) measure throughput with a
fixed live set; this bench measures what a deployment actually ships:
requests arrive on their OWN wall-clock schedule (Poisson interarrivals,
heavy-tailed prompt lengths, bimodal decode lengths), the server admits
what fits, and the reported numbers are the DISTRIBUTION of
time-to-first-token and inter-token latency — p50 and p99, not means,
because the p99 is where head-of-line blocking lives.

Two scenarios at EQUAL KV memory and EQUAL batch width:

  * ``fifo_contiguous`` — the contiguous slot arena, FIFO admission: a
    long-running request holds its slot to completion, so a short
    request that arrives behind ~`max_batch` long ones waits for a
    full decode before its first token.  That wait IS the p99 TTFT.
  * ``paged_v2`` — the paged arena with a fixed page budget equal to
    the contiguous scenario's KV footprint, policy="v2" with
    ``preempt_tokens`` rotation: after a quantum of tokens a long
    request SUSPENDS (its KV pages detach into a handle — resident,
    unwritable, re-attached to a free slot on resume with zero
    recompute) and a waiting request takes the slot.  Tail TTFT is
    bounded by the rotation quantum instead of the longest decode, and
    because suspension costs a host table rewrite rather than a
    re-prefill, throughput stays within noise of FIFO.  Under page
    pressure the v2 policy strips the worst-ranked suspended handle
    (demoting it to an honest re-prefill eviction), so the fixed
    budget is never oversubscribed.

Outputs are bit-identical between scenarios (per-request randomness is
(uid, blocks)-keyed and the buffer length is pinned via
``min_buf_len``), so the comparison is pure scheduling — same tokens,
different tail.  ``bit_identical`` rides in the payload and CI gates
on it.  The nightly perf gates:

  * ``ttft_p99_improvement >= 2`` — the headline claim.
  * ``paging_tokens_per_s_ratio >= 0.8`` — "no tokens/s regression"
    from the paged MECHANISM, isolated from policy: the same trace
    drained closed-loop under FIFO on both arenas.  The paged fused
    round runs the identical contiguous program on a persistent
    gathered view (engine_cached §12), so this sits at parity
    (~0.95+); the margin is CPU wall-clock noise.

``rotation_tokens_per_s_ratio`` (open-loop, makespan-based) is
REPORTED, not gated: rotating long requests under an equal-memory
budget genuinely costs throughput — each strip demotes a suspended
handle to a re-prefill — and on this trace the cost is ~30% for a
3-6x tail win.  That trade is the policy's documented price, not a
regression; deployments tune it with ``preempt_tokens``.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from benchmarks.lm_pair import bench_prompts, get_pair
from repro.specdec import CachedSpecDecEngine, SpecDecConfig, SpecDecServer

K = 2
L = 3
PAGE = 8
BATCH = 4               # both scenarios: equal compute per round
PREEMPT_TOKENS = 32     # rotation quantum (tokens per stint)
MEAN_GAP_S = 0.12       # Poisson interarrival mean — near saturation
# Warm prompts: the short set exercises admission + the fused round;
# the long set tiles every power-of-two prefill bucket up to 128 so a
# mid-run re-prefill (a stripped suspend handle re-admitting) never
# pays a compile on the clock.
WARM_SHORT = (3, 5, 9, 17, 33)
WARM_BUCKETS = (65, 129, 176)


def _trace(n: int, max_new_short: int, max_new_long: int, seed: int = 17,
           mean_gap_s: float = MEAN_GAP_S):
    """Poisson arrivals, heavy-tailed (Pareto) prompt lengths, bimodal
    decode lengths: ~3 in 10 requests decode ``max_new_long`` tokens —
    the requests that monopolize FIFO slots and create the TTFT tail
    this bench exists to measure."""
    rng = np.random.default_rng(seed)
    arrive = np.cumsum(rng.exponential(mean_gap_s, size=n))
    lens = np.minimum(4 + (rng.pareto(2.0, size=n) * 8).astype(int), 48)
    base = bench_prompts(n, length=int(lens.max()) + 1)
    prompts = [p[:int(m)] for p, m in zip(base, lens)]
    # Long/short mix is DETERMINISTIC (every 3rd request long) so the
    # head-of-line pressure the bench measures is stationary across
    # trace sizes — a small-sample random draw can cluster its longs
    # where they never stack 4-deep, and then FIFO shows no tail at
    # all and the comparison measures luck, not scheduling.
    max_news = np.where(np.arange(n) % 3 == 0, max_new_long,
                        max_new_short).tolist()
    min_buf = max(len(p) for p in prompts) + max(max_news) + L + 2
    # Cover the warm prompts too: warming must never grow the pool
    # buffer past the pinned length (buffer LENGTH changes compiled
    # reduction shapes, which would break paged-vs-contiguous
    # bit-identity between scenarios).
    min_buf = max(min_buf,
                  max(WARM_SHORT) + max_new_long + L + 2,
                  max(WARM_BUCKETS) + 4 + L + 2)
    return arrive, prompts, max_news, min_buf


def _serve_open_loop(srv, prompts, arrive, max_news, key):
    """Drive the server against the wall-clock arrival schedule."""
    done = []
    t0 = time.perf_counter()
    i = 0
    while i < len(prompts) or srv.queue or srv.live:
        now = time.perf_counter() - t0
        while i < len(prompts) and arrive[i] <= now:
            srv.submit(prompts[i], max_new=max_news[i])
            i += 1
        if not (srv.queue or srv.live):
            time.sleep(min(arrive[i] - now, 0.005))
            continue
        done.extend(srv.step(key))
    return done


def _latency_stats(done):
    ttfts = np.array([r.ttft_ms for r in done])
    itls = np.concatenate([r.itl_ms for r in done if len(r.itl_ms)] or
                          [np.zeros(1)])
    return {
        "ttft_p50_ms": float(np.percentile(ttfts, 50)),
        "ttft_p99_ms": float(np.percentile(ttfts, 99)),
        "itl_p50_ms": float(np.percentile(itls, 50)),
        "itl_p99_ms": float(np.percentile(itls, 99)),
    }


def _scenario(pair, *, paged: bool, min_buf: int):
    """Build (engine, server factory) for one arena.  The factory takes
    policy overrides so one warmed engine serves both the open-loop
    policy run and the closed-loop FIFO parity run.  The paged
    scenario's fixed page budget equals the contiguous scenario's KV
    footprint: BATCH slots x K rows x ceil(min_buf / PAGE) pages."""
    target, drafter = pair
    sd = SpecDecConfig(num_drafts=K, draft_len=L, strategy="gls", top_k=50,
                       paged=paged, page_size=PAGE)
    if paged:
        budget = BATCH * K * -(-min_buf // PAGE)
        eng = CachedSpecDecEngine(target, drafter, sd,
                                  pool_slots=BATCH, pool_pages=budget)
    else:
        eng = CachedSpecDecEngine(target, drafter, sd, pool_slots=BATCH)

    def make(**policy_kw):
        return SpecDecServer(eng, max_batch=BATCH, cache_mode="kv_fused",
                             min_buf_len=min_buf, **policy_kw)

    return eng, make


def _drain_closed(make, prompts, max_news, key):
    """Closed-loop FIFO drain of the trace (all requests queued at
    t=0): tokens / makespan is the policy-free throughput of the
    ARENA, the number the mechanism-parity gate compares.  Best of
    two drains (each on a FRESH server, so uids — and with them the
    (uid, blocks)-keyed randomness — restart identically): single
    closed drains swing ±15% on shared-CPU wall clocks, which would
    flake the parity gate."""
    best, done = 0.0, None
    for _ in range(2):
        srv = make()
        for p, mn in zip(prompts, max_news):
            srv.submit(p, max_new=mn)
        t0 = time.perf_counter()
        done = []
        while srv.queue or srv.live:
            done.extend(srv.step(key))
        makespan = time.perf_counter() - t0
        toks = sum(len(r.output) for r in done)
        best = max(best, toks / makespan)
    return done, best


def collect(*, n_requests: int = 24, max_new_short: int = 8,
            max_new_long: int = 128) -> dict:
    pair = get_pair()
    arrive, prompts, max_news, min_buf = _trace(
        n_requests, max_new_short, max_new_long)
    key = jax.random.PRNGKey(23)
    payload = {"n_requests": n_requests,
               "max_new": sorted(set(max_news)),
               "prompt_lens": [len(p) for p in prompts]}
    scenarios = {
        "fifo_contiguous": (False, {}),
        "paged_v2": (True, dict(policy="v2",
                                preempt_tokens=PREEMPT_TOKENS)),
    }
    outputs = {}
    for name, (paged, policy_kw) in scenarios.items():
        eng, make = _scenario(pair, paged=paged, min_buf=min_buf)
        # Warm pass, off the clock: compiles the fused round, the v2
        # rotation machinery (suspend/resume sync on the paged
        # engine), and — via WARM_BUCKETS — every prefill bucket a
        # mid-run (re-)admission can hit.
        warm = make(**policy_kw)
        for n in WARM_SHORT:
            warm.submit(np.arange(1, 1 + n, dtype=np.int32),
                        max_new=max_new_long)
        for n in WARM_BUCKETS:
            warm.submit(np.arange(1, 1 + n, dtype=np.int32) % 31 + 1,
                        max_new=4)
        warm.run(key)
        if paged:
            # Second, short warm pass: the paged engine compiles a
            # DIFFERENT prefill program per bucket depending on
            # whether the fused view is live (admissions prefill into
            # the view) or absent (admissions scatter through the page
            # table).  Pass 1 hit some buckets pre-view (its first
            # admission wave); rerunning the same bucket tiling with
            # the view persisting from pass 1 compiles the view-path
            # entries too — otherwise a mid-run admission pays a
            # ~0.5s compile on the serving clock.
            warm = make(**policy_kw)
            for n in WARM_SHORT:
                warm.submit(np.arange(1, 1 + n, dtype=np.int32),
                            max_new=8)
            for n in WARM_BUCKETS:
                warm.submit(np.arange(1, 1 + n, dtype=np.int32) % 31 + 1,
                            max_new=4)
            warm.run(key)
        assert eng.pool.buf_len == min_buf, \
            "warm pass grew the pinned buffer — bit-identity would break"
        srv = make(**policy_kw)
        t0 = time.perf_counter()
        done = _serve_open_loop(srv, prompts, arrive, max_news, key)
        makespan = time.perf_counter() - t0
        stats = _latency_stats(done)
        toks = sum(len(r.output) for r in done)
        stats["tokens_per_s"] = toks / makespan
        stats["evictions"] = srv.metrics.evictions
        stats["preemptions"] = srv.metrics.preemptions
        stats["draft_syncs"] = srv.metrics.draft_syncs
        payload[name] = stats
        outputs[name] = {r.uid: list(r.output) for r in done}
        # Mechanism parity: drain the SAME trace closed-loop under
        # FIFO on this arena — policy out of the picture.
        fifo_done, fifo_tps = _drain_closed(make, prompts, max_news,
                                            key)
        payload[name]["closed_fifo_tokens_per_s"] = fifo_tps
        outputs[name + "/closed"] = {r.uid: list(r.output)
                                     for r in fifo_done}
    payload["bit_identical"] = all(
        o == outputs["fifo_contiguous"] for o in outputs.values())
    payload["ttft_p99_improvement"] = (
        payload["fifo_contiguous"]["ttft_p99_ms"]
        / max(payload["paged_v2"]["ttft_p99_ms"], 1e-9))
    # Gated: the paged arena itself must not regress throughput.
    payload["paging_tokens_per_s_ratio"] = (
        payload["paged_v2"]["closed_fifo_tokens_per_s"]
        / max(payload["fifo_contiguous"]["closed_fifo_tokens_per_s"],
              1e-9))
    # Reported: rotation's open-loop cost (the tail/throughput trade).
    payload["rotation_tokens_per_s_ratio"] = (
        payload["paged_v2"]["tokens_per_s"]
        / max(payload["fifo_contiguous"]["tokens_per_s"], 1e-9))
    return payload


def run(fast: bool = False) -> dict:
    payload = collect(n_requests=24 if fast else 48)
    for name in ("fifo_contiguous", "paged_v2"):
        s = payload[name]
        emit(f"open_loop_{name}", s["ttft_p99_ms"] * 1e3,
             f"ttft_p50={s['ttft_p50_ms']:.1f}ms "
             f"ttft_p99={s['ttft_p99_ms']:.1f}ms "
             f"itl_p99={s['itl_p99_ms']:.1f}ms "
             f"tok/s={s['tokens_per_s']:.1f}")
    emit("open_loop_summary", 0.0,
         f"p99_ttft_improvement={payload['ttft_p99_improvement']:.2f}x "
         f"paging_tok/s_ratio={payload['paging_tokens_per_s_ratio']:.2f} "
         f"rotation_tok/s_ratio="
         f"{payload['rotation_tokens_per_s_ratio']:.2f} "
         f"bit_identical={payload['bit_identical']}")
    return payload


if __name__ == "__main__":
    run(fast=True)
