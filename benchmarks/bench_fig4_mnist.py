"""Paper Fig. 4 / Tables 8-9: β-VAE distributed image compression on
(synthetic) MNIST — rate-distortion for GLS vs shared-randomness baseline
over K decoders and rates.  Coding runs through the batched compression
pipeline (``compress_batch`` chunks, DESIGN.md §10) — one device program
and one race dispatch per chunk of test images."""

from __future__ import annotations

import os
import time

import jax

from benchmarks.common import emit
from repro.compression import VAETrainConfig, evaluate_rd, train_vae
from repro.data.mnist import digits_dataset
from repro.train import load_checkpoint, save_checkpoint

CKPT = os.path.join(os.path.dirname(__file__), "..", "checkpoints",
                    "bench_vae.msgpack")


def _params(fast: bool):
    os.makedirs(os.path.dirname(CKPT), exist_ok=True)
    if os.path.exists(CKPT):
        return load_checkpoint(CKPT)["params"]
    imgs, _ = digits_dataset(1200 if fast else 4000, seed=0)
    params = train_vae(jax.random.PRNGKey(0), imgs,
                       VAETrainConfig(steps=150 if fast else 600, beta=0.35),
                       log=lambda *_: None)
    save_checkpoint(CKPT, {"params": params})
    return params


def run(fast: bool = False, backend: str = "xla"):
    params = _params(fast)
    test, _ = digits_dataset(400, seed=1)
    rows = {}
    trials = 24 if fast else 64
    for k in (1, 2) if fast else (1, 2, 4):
        for l_max in (4, 32):
            t0 = time.perf_counter()
            g = evaluate_rd(jax.random.PRNGKey(1), params, test,
                            n_atoms=256, l_max=l_max, k=k, trials=trials,
                            backend=backend)
            b = evaluate_rd(jax.random.PRNGKey(1), params, test,
                            n_atoms=256, l_max=l_max, k=k, trials=trials,
                            shared_sheet=True, backend=backend)
            dt_us = (time.perf_counter() - t0) * 1e6
            rows[(k, l_max)] = (g, b)
            emit(f"fig4_mnist_K{k}_L{l_max}", dt_us,
                 f"gls_mse={g['mse']:.4f};base_mse={b['mse']:.4f};"
                 f"gls_match={g['match_prob_any']:.3f};"
                 f"base_match={b['match_prob_any']:.3f}")
    return rows


if __name__ == "__main__":
    run()
