"""Batched Wyner–Ziv pipeline benchmark (DESIGN.md §10, §11).

Gaussian-source compression rounds (paper Sec. 5) three ways:

  * ``loop``   — the per-sample oracle: one host-driven ``wz_round``
                 dispatch + device->host sync per round;
  * ``xla``    — the batched pipeline, B rounds as one jitted program
                 (single ``gls_binned_race`` dispatch, jnp backend);
  * ``pallas`` — same program racing through the Pallas kernel in its
                 DEFAULT execution mode (compiled on TPU/GPU; on hosts
                 without compiled Pallas the resolved fallback — the
                 re-sequenced row-race path — must hold its own against
                 the xla leg, not hide behind interpret-mode excuses).

Both batched legs are timed SYMMETRICALLY (same reps, same best-of-N,
all jits warmed before any timing) — the CI gate is pallas >= xla
samples/s AND exact output equality, whatever mode resolves.

Checks, reported in the JSON payload run.py --quick merges into
BENCH_specdec.json: xla↔pallas outputs exactly equal on the same round
keys; the empirical any-decoder match rate meets the Prop.-4 lower
bound; the batched xla path does not regress samples/s vs the loop.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.compression import GaussianWZ, simulate_trial
from repro.compression.gaussian import _batch_trials
from repro.kernels.gls_race.ops import resolve_race_mode

B_FAST, B_FULL = 256, 512
N_FAST, N_FULL = 2 ** 14, 2 ** 15
K, L_MAX = 2, 4


_REPS = 3  # best-of-N timing absorbs shared-runner noise


def _best_of(fn, *args, reps=_REPS):
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return out, best


def run(fast: bool = True):
    b = B_FAST if fast else B_FULL
    cfg = GaussianWZ(sigma2_w_given_a=0.01,
                     n_atoms=N_FAST if fast else N_FULL)
    keys = jax.random.split(jax.random.PRNGKey(0), b)

    trial = jax.jit(lambda kk: simulate_trial(kk, cfg, K, L_MAX))
    fns = {be: jax.jit(lambda kk, be=be: _batch_trials(
        kk, cfg, K, L_MAX, False, be, None)) for be in ("xla", "pallas")}

    # Warm EVERY jit cache before timing ANY leg: a compile riding
    # inside another leg's timed region is the classic roofline lie.
    jax.block_until_ready(trial(keys[0]))
    for fn in fns.values():
        jax.block_until_ready(fn(keys))

    # Host-driven per-sample loop (the pre-pipeline serving path).
    loop_s = float("inf")
    for _ in range(_REPS):
        t0 = time.perf_counter()
        for i in range(b):
            m, s, _ = trial(keys[i])
            float(s)               # the per-round host sync
        loop_s = min(loop_s, time.perf_counter() - t0)

    backends = {}
    outs = {}
    for backend, fn in fns.items():
        (match, best_sq, infos), dt = _best_of(fn, keys)
        outs[backend] = (np.asarray(match), np.asarray(best_sq),
                         np.asarray(infos))
        backends[backend] = {
            "samples_per_s": b / dt,
            "us_per_batch": dt * 1e6,
        }

    equal = all(
        np.array_equal(outs["xla"][i], outs["pallas"][i]) for i in range(3))
    match, _, infos = outs["xla"]
    from repro.core.bounds import wz_error_upper_bound
    import jax.numpy as jnp
    match_rate = float(np.mean(match.any(axis=1)))
    bound = float(1.0 - wz_error_upper_bound(jnp.asarray(infos), K, L_MAX))

    loop_rate = b / loop_s
    pallas_vs_xla = (backends["pallas"]["samples_per_s"]
                     / backends["xla"]["samples_per_s"])
    payload = {
        "batch": b,
        "n_atoms": cfg.n_atoms,
        "k": K,
        "l_max": L_MAX,
        "race_mode": resolve_race_mode(None),
        "loop_samples_per_s": loop_rate,
        "xla": backends["xla"],
        "pallas": backends["pallas"],
        "equal_xla_pallas": bool(equal),
        "pallas_vs_xla": pallas_vs_xla,
        "pallas_ge_xla": bool(pallas_vs_xla >= 1.0),
        "match_rate_any": match_rate,
        "match_lower_bound": bound,
        "bound_satisfied": bool(match_rate >= bound - 0.05),
        "pipeline_speedup_vs_loop":
            backends["xla"]["samples_per_s"] / loop_rate,
    }
    emit("wz_pipeline_tokens_per_s", backends["xla"]["us_per_batch"],
         f"xla={backends['xla']['samples_per_s']:.0f}/s;"
         f"pallas={backends['pallas']['samples_per_s']:.0f}/s;"
         f"loop={loop_rate:.0f}/s;"
         f"mode={payload['race_mode']};"
         f"pallas_vs_xla={pallas_vs_xla:.2f}x;"
         f"speedup={payload['pipeline_speedup_vs_loop']:.1f}x;"
         f"equal={equal}")
    emit("wz_pipeline_match_rate", 0.0,
         f"match={match_rate:.3f};bound={bound:.3f};"
         f"ok={payload['bound_satisfied']}")
    return payload


if __name__ == "__main__":
    run()
