"""Batched Wyner–Ziv pipeline benchmark (DESIGN.md §10).

Gaussian-source compression rounds (paper Sec. 5) three ways:

  * ``loop``   — the per-sample oracle: one host-driven ``wz_round``
                 dispatch + device->host sync per round;
  * ``xla``    — the batched pipeline, B rounds as one jitted program
                 (single ``gls_binned_race`` dispatch, jnp backend);
  * ``pallas`` — same program racing through the Pallas kernel
                 (interpret mode on CPU — dispatch structure, not speed,
                 is what the backend demonstrates here).

Checks, reported in the JSON payload run.py --quick merges into
BENCH_specdec.json: xla↔pallas outputs exactly equal on the same round
keys; the empirical any-decoder match rate meets the Prop.-4 lower
bound; the batched xla path does not regress samples/s vs the loop.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.compression import GaussianWZ, simulate_trial
from repro.compression.gaussian import _batch_trials

B_FAST, B_FULL = 256, 512
N_FAST, N_FULL = 2 ** 14, 2 ** 15
K, L_MAX = 2, 4


_REPS = 3  # best-of-N timing absorbs shared-runner noise


def _timed(fn, *args, reps=_REPS):
    fn(*args)                      # warm the jit cache
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return out, best


def run(fast: bool = True):
    b = B_FAST if fast else B_FULL
    cfg = GaussianWZ(sigma2_w_given_a=0.01,
                     n_atoms=N_FAST if fast else N_FULL)
    keys = jax.random.split(jax.random.PRNGKey(0), b)

    # Host-driven per-sample loop (the pre-pipeline serving path).
    trial = jax.jit(lambda kk: simulate_trial(kk, cfg, K, L_MAX))
    trial(keys[0])                 # warm
    loop_s = float("inf")
    for _ in range(_REPS):
        t0 = time.perf_counter()
        for i in range(b):
            m, s, _ = trial(keys[i])
            float(s)               # the per-round host sync
        loop_s = min(loop_s, time.perf_counter() - t0)

    backends = {}
    outs = {}
    for backend in ("xla", "pallas"):
        # The pallas leg runs in interpret mode here (no TPU): coarsen
        # the atom tile to amortize per-program overhead and time a
        # single rep — outputs are tiling-invariant and only the
        # equivalence check consumes them, the perf gate is xla-vs-loop.
        tile = 8192 if backend == "pallas" else None
        reps = 1 if backend == "pallas" else _REPS
        fn = jax.jit(lambda kk, be=backend, tn=tile: _batch_trials(
            kk, cfg, K, L_MAX, False, be, True, tile_n=tn))
        (match, best_sq, infos), dt = _timed(fn, keys, reps=reps)
        outs[backend] = (np.asarray(match), np.asarray(best_sq),
                         np.asarray(infos))
        backends[backend] = {
            "samples_per_s": b / dt,
            "us_per_batch": dt * 1e6,
        }

    equal = all(
        np.array_equal(outs["xla"][i], outs["pallas"][i]) for i in range(3))
    match, _, infos = outs["xla"]
    from repro.core.bounds import wz_error_upper_bound
    import jax.numpy as jnp
    match_rate = float(np.mean(match.any(axis=1)))
    bound = float(1.0 - wz_error_upper_bound(jnp.asarray(infos), K, L_MAX))

    loop_rate = b / loop_s
    payload = {
        "batch": b,
        "n_atoms": cfg.n_atoms,
        "k": K,
        "l_max": L_MAX,
        "loop_samples_per_s": loop_rate,
        "xla": backends["xla"],
        "pallas": backends["pallas"],
        "equal_xla_pallas": bool(equal),
        "match_rate_any": match_rate,
        "match_lower_bound": bound,
        "bound_satisfied": bool(match_rate >= bound - 0.05),
        "pipeline_speedup_vs_loop":
            backends["xla"]["samples_per_s"] / loop_rate,
    }
    emit("wz_pipeline_tokens_per_s", backends["xla"]["us_per_batch"],
         f"xla={backends['xla']['samples_per_s']:.0f}/s;"
         f"pallas={backends['pallas']['samples_per_s']:.0f}/s;"
         f"loop={loop_rate:.0f}/s;"
         f"speedup={payload['pipeline_speedup_vs_loop']:.1f}x;"
         f"equal={equal}")
    emit("wz_pipeline_match_rate", 0.0,
         f"match={match_rate:.3f};bound={bound:.3f};"
         f"ok={payload['bound_satisfied']}")
    return payload


if __name__ == "__main__":
    run()
