"""Paper Fig. 6: token-level acceptance on random toy distributions,
GLS vs SpecTr vs SpecInfer vs the with-communication upper bound, as the
number of drafts K varies."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import iid_draft_acceptance_upper, lml_bound
from repro.specdec import (
    draft_token_from_uniforms,
    gls_verify,
    specinfer_verify,
    spectr_verify,
)

N = 10
N_DISTS = 100       # paper: 100 random instances
TRIALS = 200        # MC trials per instance
KS = (1, 2, 4, 8, 16, 20)


def _accept_rate(strategy: str, p, q, k: int, key) -> float:
    def one(kk):
        k_u, k_s = jax.random.split(kk)
        log_u = jnp.log(jax.random.uniform(k_u, (k, N), minval=1e-37,
                                           maxval=1.0))
        d = draft_token_from_uniforms(log_u, jnp.broadcast_to(p, (k, N)))
        active = jnp.ones((k,), bool)
        qk = jnp.broadcast_to(q, (k, N))
        pk = jnp.broadcast_to(p, (k, N))
        if strategy == "gls":
            return gls_verify(log_u, d, qk, active).accepted
        if strategy == "specinfer":
            return specinfer_verify(k_s, pk, d, qk, active).accepted
        return spectr_verify(k_s, pk, d, qk, active).accepted
    keys = jax.random.split(key, TRIALS)
    return float(jnp.mean(jax.vmap(one)(keys)))


def run(seed: int = 0):
    key = jax.random.PRNGKey(seed)
    rows = {}
    t0 = time.perf_counter()
    for k in KS:
        accs = {s: [] for s in ("gls", "specinfer", "spectr")}
        lmls, uppers = [], []
        for i in range(N_DISTS):
            kk = jax.random.fold_in(key, i * 100 + k)
            kp, kq, kt = jax.random.split(kk, 3)
            p = jax.random.dirichlet(kp, jnp.ones(N))
            q = jax.random.dirichlet(kq, jnp.ones(N))
            for s in accs:
                accs[s].append(_accept_rate(s, p, q, k, kt))
            lmls.append(float(lml_bound(p, q, k)))
            uppers.append(float(iid_draft_acceptance_upper(p, q, k)))
        rows[k] = {
            "gls": float(np.mean(accs["gls"])),
            "specinfer": float(np.mean(accs["specinfer"])),
            "spectr": float(np.mean(accs["spectr"])),
            "lml_bound": float(np.mean(lmls)),
            "upper_bound": float(np.mean(uppers)),
        }
    us = (time.perf_counter() - t0) * 1e6 / (len(KS) * N_DISTS * 3)
    for k, r in rows.items():
        emit(f"fig6_toy_acceptance_K{k}", us,
             f"gls={r['gls']:.3f};specinfer={r['specinfer']:.3f};"
             f"spectr={r['spectr']:.3f};lml={r['lml_bound']:.3f};"
             f"upper={r['upper_bound']:.3f}")
    return rows


if __name__ == "__main__":
    run()
