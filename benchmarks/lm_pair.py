"""Shared trained target/drafter pair for the spec-dec benchmarks
(CPU-scale stand-ins for the paper's Qwen 7B / 0.5B pair; cached under
checkpoints/)."""

from __future__ import annotations

import os

import jax
import numpy as np

from repro.data import lm_dataset, synthetic_corpus, encode
from repro.models import ModelConfig, init_params
from repro.train import TrainConfig, load_checkpoint, save_checkpoint, train

CKPT = os.path.join(os.path.dirname(__file__), "..", "checkpoints",
                    "bench_lm.msgpack")

VOCAB = 128

TARGET_CFG = ModelConfig(
    name="bench-target", family="dense", num_layers=4, d_model=256,
    num_heads=8, num_kv_heads=4, head_dim=32, d_ff=512, vocab_size=VOCAB,
    dtype="float32")
# Deliberately weaker + briefly trained: the drafter must be meaningfully
# misaligned with the target or every strategy saturates at BE = L+1.
DRAFT_CFG = ModelConfig(
    name="bench-drafter", family="dense", num_layers=1, d_model=96,
    num_heads=4, num_kv_heads=2, head_dim=24, d_ff=192, vocab_size=VOCAB,
    dtype="float32")


def get_pair(steps: int = 200, log=lambda *_: None):
    """Returns ((target_params, TARGET_CFG), (draft_params, DRAFT_CFG))."""
    os.makedirs(os.path.dirname(CKPT), exist_ok=True)
    if os.path.exists(CKPT):
        ck = load_checkpoint(CKPT)
        return (ck["target"], TARGET_CFG), (ck["drafter"], DRAFT_CFG)
    tparams = init_params(jax.random.PRNGKey(0), TARGET_CFG)
    dparams = init_params(jax.random.PRNGKey(1), DRAFT_CFG)
    ds_t = lm_dataset(16, 128, VOCAB, seed=0, num_sentences=6000)
    ds_d = lm_dataset(16, 128, VOCAB, seed=1, num_sentences=6000)
    tc = TrainConfig(total_steps=steps, log_every=max(steps // 4, 1), lr=1e-3)
    tparams, _ = train(tparams, TARGET_CFG, tc, ds_t, log=log)
    tc_d = TrainConfig(total_steps=max(steps // 4, 1), lr=1e-3,
                       log_every=max(steps // 4, 1))
    dparams, _ = train(dparams, DRAFT_CFG, tc_d, ds_d, log=log)
    save_checkpoint(CKPT, {"target": tparams, "drafter": dparams})
    return (tparams, TARGET_CFG), (dparams, DRAFT_CFG)


def bench_prompts(n: int = 4, length: int = 16) -> list:
    toks = encode(synthetic_corpus(50, seed=7)) % VOCAB
    return [np.asarray(toks[i * 37:i * 37 + length], np.int32)
            for i in range(n)]
