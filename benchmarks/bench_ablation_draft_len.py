"""Ablation (beyond the paper's tables): block efficiency vs draft length
L at fixed K, on the KV-cached production engine.  The paper fixes L=4
(i.i.d.) / L=5 (diverse); this sweep shows the BE saturation that
motivates those choices."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from benchmarks.lm_pair import bench_prompts, get_pair
from repro.specdec import SpecDecConfig
from repro.specdec.engine_cached import CachedSpecDecEngine

LS = (1, 2, 4, 8)
K = 8


def run(fast: bool = False):
    target, drafter = get_pair()
    prompts = bench_prompts(2)
    ls = (2, 4) if fast else LS
    rows = {}
    for L in ls:
        eng = CachedSpecDecEngine(
            target, drafter,
            SpecDecConfig(num_drafts=K, draft_len=L, strategy="gls",
                          top_k=50, max_new_tokens=32))
        t0 = time.perf_counter()
        stats = [eng.generate(jax.random.PRNGKey(300 + i), p)
                 for i, p in enumerate(prompts)]
        dt_us = (time.perf_counter() - t0) * 1e6 / len(prompts)
        be = float(np.mean([s.block_efficiency for s in stats]))
        acc = float(np.mean([s.accepted_drafts / max(s.blocks * L, 1)
                             for s in stats]))
        rows[L] = be
        emit(f"ablation_draftlen_L{L}_K{K}", dt_us,
             f"BE={be:.3f};draft_accept_rate={acc:.3f}")
    return rows


if __name__ == "__main__":
    run()
