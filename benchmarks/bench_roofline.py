"""Roofline benchmark: reads the dry-run sweep artifacts (one JSON per
arch x shape x mesh) and emits the per-device roofline terms — the data
behind EXPERIMENTS.md §Roofline.

Conventions (see EXPERIMENTS.md §Roofline notes):
  * compute term uses ANALYTIC model FLOPs (XLA cost_analysis counts
    lax.scan bodies once);
  * memory term uses HLO bytes-accessed (weight streams are counted
    exactly once per step by construction; CPU-backend bf16->f32 converts
    inflate weight bytes ~2x, recorded as-is);
  * collective term is loop-aware (while-loop trip counts parsed from the
    HLO and propagated through nesting).

Also microbenches the fused block-verification op (block_verify.py) on
both backends: the (L+1, K, N) race table is streamed once — ~3 flops
per cell against 4 bytes of uniforms + 4 of probs — so the op is firmly
memory-bound and its analytic bytes/flops are emitted alongside measured
wall-clock.  The "pallas" rows run the gls_race row kernel in interpret
mode on CPU (this container has no TPU); on-device numbers come from the
same call with interpret=False.
"""

from __future__ import annotations

import glob
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit

SWEEP_DIR = os.path.join(os.path.dirname(__file__), "..", "dryrun_results",
                         "sweep")


def _verify_block_rows(fast: bool):
    """Measured + analytic roofline rows for the fused verifier."""
    from repro.specdec.block_verify import block_verify as fused_verify

    l_n, n = 4, 2048
    reps = 5 if fast else 20
    rows = []
    for k in (2, 8):
        kk = jax.random.PRNGKey(0)
        ku, kq, kd = jax.random.split(kk, 3)
        log_u = jnp.log(jax.random.uniform(
            ku, (l_n + 1, k, n), minval=np.finfo(np.float32).tiny,
            maxval=1.0))
        q = jax.random.dirichlet(kq, jnp.ones(n), (k, l_n + 1))
        d = jax.random.randint(kd, (k, l_n), 0, n, jnp.int32)
        strat_keys = jax.random.split(kk, l_n + 1)
        cells = (l_n + 1) * k * n
        bytes_accessed = 2 * 4 * cells          # uniforms + target probs
        flops = 3 * cells                       # log, sub, min-reduce
        for backend in ("xla", "pallas"):
            fn = lambda: fused_verify(
                log_u, d, None, q, strat_keys, strategy="gls",
                backend=backend).tokens.block_until_ready()
            fn()  # warmup/compile
            t0 = time.perf_counter()
            for _ in range(reps):
                fn()
            us = (time.perf_counter() - t0) * 1e6 / reps
            rows.append((f"verify_block_{backend}_K{k}", us,
                         f"bytes={bytes_accessed};flops={flops};"
                         f"intensity={flops / bytes_accessed:.2f};"
                         f"L={l_n};N={n};interpret=True"))
    return rows


def run(fast: bool = False):
    rows = []
    for path in sorted(glob.glob(os.path.join(SWEEP_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r["status"] != "ok":
            continue
        rl = r["roofline"]
        pd = r["per_device"]
        hlo_ratio = pd.get("model_flops_global", 0.0) / max(
            pd.get("hlo_flops_scanbody", 0.0), 1.0)
        emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
             r.get("compile_s", 0) * 1e6,
             f"compute_s={rl['compute_s']:.3e};memory_s={rl['memory_s']:.3e};"
             f"collective_s={rl['collective_s']:.3e};"
             f"bound={rl['bottleneck']};model_vs_hlo_flops={hlo_ratio:.1f}")
        rows.append(r)
    if not rows:
        emit("roofline_missing", 0.0,
             "run repro.launch.sweep first (dryrun_results/sweep)")
    for name, us, derived in _verify_block_rows(fast):
        emit(name, us, derived)
    return rows


if __name__ == "__main__":
    run()
