"""Roofline benchmark: reads the dry-run sweep artifacts (one JSON per
arch x shape x mesh) and emits the per-device roofline terms — the data
behind EXPERIMENTS.md §Roofline.

Conventions (see EXPERIMENTS.md §Roofline notes):
  * compute term uses ANALYTIC model FLOPs (XLA cost_analysis counts
    lax.scan bodies once);
  * memory term uses HLO bytes-accessed (weight streams are counted
    exactly once per step by construction; CPU-backend bf16->f32 converts
    inflate weight bytes ~2x, recorded as-is);
  * collective term is loop-aware (while-loop trip counts parsed from the
    HLO and propagated through nesting).

Also microbenches the list-coupling hot kernels — the fused block
verifier (block_verify.py) and the gls_race row/binned kernels — on both
backends in their DEFAULT execution mode (DESIGN.md §11).  Every kernel
row reports analytic bytes moved, achieved GB/s, and the fraction of the
MEMORY-BOUND peak, where the peak is self-calibrated on this host by
timing a streaming f32 copy (the kernels are all ~O(1) flops/byte, so
the copy bandwidth IS their roofline).  Timing discipline: every jit in
the table is warmed before ANY row is timed — a compile riding inside
another row's timed region is the classic microbenchmark lie — and each
row reports best-of-N.
"""

from __future__ import annotations

import glob
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit

SWEEP_DIR = os.path.join(os.path.dirname(__file__), "..", "dryrun_results",
                         "sweep")


def _best_of(fn, reps):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _copy_bandwidth(reps: int = 5) -> float:
    """Self-calibrated memory-bound peak: bytes/s of a streaming f32
    copy (read + write) big enough to defeat caches."""
    x = jnp.arange(8 * 2 ** 20, dtype=jnp.float32)   # 32 MiB
    fn = jax.jit(lambda a: a * 1.0)
    jax.block_until_ready(fn(x))                     # warm
    best = _best_of(lambda: fn(x), reps)
    return 2 * x.nbytes / best


def _kernel_cases(fast: bool):
    """(name, thunk, bytes, flops, extra) rows for the coupling kernels.
    Thunks close over jitted callables; nothing is timed here."""
    from repro.kernels.gls_race.ops import (
        gls_binned_race_op,
        gls_row_race_op,
        resolve_race_mode,
    )
    from repro.specdec.block_verify import block_verify as fused_verify

    mode = resolve_race_mode(None)
    cases = []

    # Fused block verifier: the (L+1, K, N) race table is streamed once —
    # ~3 flops per cell against 4 bytes of uniforms + 4 of probs.
    l_n, n = 4, 2048
    for k in (2, 8):
        kk = jax.random.PRNGKey(0)
        ku, kq, kd = jax.random.split(kk, 3)
        log_u = jnp.log(jax.random.uniform(
            ku, (l_n + 1, k, n), minval=np.finfo(np.float32).tiny,
            maxval=1.0))
        q = jax.random.dirichlet(kq, jnp.ones(n), (k, l_n + 1))
        d = jax.random.randint(kd, (k, l_n), 0, n, jnp.int32)
        strat_keys = jax.random.split(kk, l_n + 1)
        cells = (l_n + 1) * k * n
        for backend in ("xla", "pallas"):
            cases.append((
                f"verify_block_{backend}_K{k}",
                lambda lu=log_u, dd=d, qq=q, sk=strat_keys, be=backend:
                    fused_verify(lu, dd, None, qq, sk, strategy="gls",
                                 backend=be).tokens,
                2 * 4 * cells, 3 * cells,
                f"L={l_n};N={n};mode={mode}"))

    # Race kernels at the WZ-pipeline shape: (B, K, N) f32 score + weight
    # streams (plus the (B, N) i32 bin map for the binned op).
    b, k, n, l_max = (128, 2, 2 ** 13, 4) if fast else (256, 2, 2 ** 14, 4)
    kk = jax.random.PRNGKey(1)
    ks_, kq_, kb_ = jax.random.split(kk, 3)
    log_s = jnp.log(jax.random.uniform(
        ks_, (b, k, n), minval=np.finfo(np.float32).tiny, maxval=1.0))
    log_q = jax.random.normal(kq_, (b, k, n))
    bins = jax.random.randint(kb_, (b, n), 0, l_max, jnp.int32)
    row_bytes = 2 * 4 * b * k * n
    bin_bytes = (2 * b * k * n + b * n) * 4
    for use_kernel, tag in ((True, "pallas"), (False, "xla")):
        cases.append((
            f"gls_row_race_{tag}",
            lambda uk=use_kernel: gls_row_race_op(log_s, log_q,
                                                  use_kernel=uk),
            row_bytes, 2 * b * k * n,
            f"B={b};K={k};N={n};mode={mode if use_kernel else 'xla'}"))
        cases.append((
            f"gls_binned_race_{tag}",
            lambda uk=use_kernel: gls_binned_race_op(
                log_s, log_q, bins, l_max=l_max, use_kernel=uk),
            bin_bytes, 3 * b * k * n,
            f"B={b};K={k};N={n};l_max={l_max};"
            f"mode={mode if use_kernel else 'xla'}"))
    return cases


def _kernel_rows(fast: bool):
    """Measured + analytic roofline rows for the coupling kernels: warm
    everything, calibrate the memory roof, then time."""
    cases = _kernel_cases(fast)
    for _, thunk, _, _, _ in cases:       # warm ALL jits first
        jax.block_until_ready(thunk())
    peak = _copy_bandwidth()
    reps = 5 if fast else 20
    rows = []
    for name, thunk, bytes_moved, flops, extra in cases:
        dt = _best_of(thunk, reps)
        gbps = bytes_moved / dt / 1e9
        rows.append((name, dt * 1e6,
                     f"bytes={bytes_moved};gbps={gbps:.2f};"
                     f"pct_mem_peak={100 * bytes_moved / dt / peak:.1f};"
                     f"intensity={flops / bytes_moved:.2f};{extra}"))
    rows.append(("copy_bandwidth_peak", 0.0,
                 f"gbps={peak / 1e9:.2f};bytes={2 * 8 * 2 ** 20 * 4}"))
    return rows


def run(fast: bool = False):
    rows = []
    for path in sorted(glob.glob(os.path.join(SWEEP_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r["status"] != "ok":
            continue
        rl = r["roofline"]
        pd = r["per_device"]
        hlo_ratio = pd.get("model_flops_global", 0.0) / max(
            pd.get("hlo_flops_scanbody", 0.0), 1.0)
        emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
             r.get("compile_s", 0) * 1e6,
             f"compute_s={rl['compute_s']:.3e};memory_s={rl['memory_s']:.3e};"
             f"collective_s={rl['collective_s']:.3e};"
             f"bound={rl['bottleneck']};model_vs_hlo_flops={hlo_ratio:.1f}")
        rows.append(r)
    if not rows:
        emit("roofline_missing", 0.0,
             "run repro.launch.sweep first (dryrun_results/sweep)")
    kernel_rows = _kernel_rows(fast)
    for name, us, derived in kernel_rows:
        emit(name, us, derived)
    return {"sweep": rows,
            "kernels": [{"name": n, "us": us, "derived": d}
                        for n, us, d in kernel_rows]}


if __name__ == "__main__":
    run()
