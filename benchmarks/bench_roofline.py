"""Roofline benchmark: reads the dry-run sweep artifacts (one JSON per
arch x shape x mesh) and emits the per-device roofline terms — the data
behind EXPERIMENTS.md §Roofline.

Conventions (see EXPERIMENTS.md §Roofline notes):
  * compute term uses ANALYTIC model FLOPs (XLA cost_analysis counts
    lax.scan bodies once);
  * memory term uses HLO bytes-accessed (weight streams are counted
    exactly once per step by construction; CPU-backend bf16->f32 converts
    inflate weight bytes ~2x, recorded as-is);
  * collective term is loop-aware (while-loop trip counts parsed from the
    HLO and propagated through nesting).
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

SWEEP_DIR = os.path.join(os.path.dirname(__file__), "..", "dryrun_results",
                         "sweep")


def run(fast: bool = False):
    rows = []
    for path in sorted(glob.glob(os.path.join(SWEEP_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r["status"] != "ok":
            continue
        rl = r["roofline"]
        pd = r["per_device"]
        hlo_ratio = pd.get("model_flops_global", 0.0) / max(
            pd.get("hlo_flops_scanbody", 0.0), 1.0)
        emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
             r.get("compile_s", 0) * 1e6,
             f"compute_s={rl['compute_s']:.3e};memory_s={rl['memory_s']:.3e};"
             f"collective_s={rl['collective_s']:.3e};"
             f"bound={rl['bottleneck']};model_vs_hlo_flops={hlo_ratio:.1f}")
        rows.append(r)
    if not rows:
        emit("roofline_missing", 0.0,
             "run repro.launch.sweep first (dryrun_results/sweep)")
    return rows


if __name__ == "__main__":
    run()
