"""Shared benchmark utilities: CSV emission + timing."""

from __future__ import annotations

import sys
import time


def emit(name: str, us_per_call: float, derived: str) -> None:
    """``name,us_per_call,derived`` CSV row (harness contract)."""
    print(f"{name},{us_per_call:.1f},{derived}")
    sys.stdout.flush()


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self.t0

    @property
    def us(self) -> float:
        return self.elapsed * 1e6
