"""Paper Fig. 2 / Tables 5-6: Gaussian source — matching probability and
rate-distortion for GLS vs the shared-randomness baseline, over
K in {1,2,4} decoders and rates log2(l_max) in {1..6} bits.

Trials stream through the batched compression pipeline (DESIGN.md §10);
each derived row also carries the Prop.-4 lower bound on the GLS
any-decoder match rate evaluated from the empirical information
densities."""

from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.compression import GaussianWZ, run_experiment

KS = (1, 2, 4)
L_MAXES = (2, 8, 64)
SIGMA2 = (0.01, 0.005, 0.001)


def run(fast: bool = False, backend: str = "xla"):
    trials = 400 if fast else 2000
    n_atoms = 1024 if fast else 4096
    key = jax.random.PRNGKey(0)
    rows = {}
    for k in KS:
        for l_max in L_MAXES:
            best = {"distortion_db": 1e9}
            best_base = {"distortion_db": 1e9}
            for s2 in SIGMA2:
                cfg = GaussianWZ(sigma2_w_given_a=s2, n_atoms=n_atoms)
                t0 = time.perf_counter()
                r = run_experiment(key, cfg, k, l_max, trials,
                                   backend=backend)
                dt_us = (time.perf_counter() - t0) * 1e6
                if r["distortion_db"] < best["distortion_db"]:
                    best = {**r, "sigma2": s2, "us": dt_us}
                rb = run_experiment(key, cfg, k, l_max, trials,
                                    shared_sheet=True, backend=backend)
                if rb["distortion_db"] < best_base["distortion_db"]:
                    best_base = {**rb, "sigma2": s2}
            rows[(k, l_max)] = (best, best_base)
            emit(f"fig2_gaussian_K{k}_L{l_max}", best["us"],
                 f"gls_db={best['distortion_db']:.2f};"
                 f"base_db={best_base['distortion_db']:.2f};"
                 f"gls_match={best['match_prob_any']:.3f};"
                 f"base_match={best_base['match_prob_any']:.3f};"
                 f"bound={best['match_lower_bound']:.3f}")
    return rows


if __name__ == "__main__":
    run()
