"""Paper Table 1/3: multi-draft speculative decoding with i.i.d. drafts —
block efficiency (BE) per strategy and draft count K, on a trained
target/drafter pair (CPU-scale stand-in for Qwen 7B/0.5B; see DESIGN.md
§6).  Token-rate speedups are replaced by BE + verified-FLOP ratios since
this container has no accelerator wall-clock; per-row tokens/s and the
verification host-sync count are still recorded so the fused-verifier
trajectory (legacy per-token loop vs one jitted block) is tracked.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from benchmarks.lm_pair import bench_prompts, get_pair
from repro.specdec import SpecDecConfig, SpecDecEngine

KS = (2, 8)
STRATEGIES = ("gls", "gls_strong", "specinfer", "spectr", "daliri")
L = 4
MAX_NEW = 48
N_PROMPTS = 3


def _measure(target, drafter, prompts, strategy, k, *, backend="xla",
             max_new=MAX_NEW):
    kk = 1 if strategy in ("daliri", "single") else k
    eng = SpecDecEngine(
        target, [drafter],
        SpecDecConfig(num_drafts=kk, draft_len=L, strategy=strategy,
                      top_k=50, max_new_tokens=max_new,
                      verifier_backend=backend))
    # Warm the jit caches at the measured buffer shape before timing —
    # whichever (strategy, K) ran first used to absorb the whole
    # process's XLA compile time and report ~2x-low tokens/s (the "gls
    # lag": gls leads the strategy loop).
    eng.gen_block(jax.random.PRNGKey(0), prompts[0],
                  len(prompts[0]) + max_new + L + 2)
    t0 = time.perf_counter()
    stats = [eng.generate(jax.random.PRNGKey(100 + i), p)
             for i, p in enumerate(prompts)]
    dt = time.perf_counter() - t0
    toks = sum(len(s.output) for s in stats)
    return {
        "strategy": strategy,
        "K": kk,
        "backend": backend,
        "block_efficiency": float(np.mean([s.block_efficiency
                                           for s in stats])),
        "tokens_per_s": toks / max(dt, 1e-9),
        "host_syncs": int(sum(s.host_syncs for s in stats)),
        "blocks": int(sum(s.blocks for s in stats)),
        "us_per_prompt": dt * 1e6 / len(prompts),
    }


def collect(ks=KS, strategies=STRATEGIES, *, backend="xla",
            max_new=MAX_NEW, n_prompts=N_PROMPTS):
    """Measured rows for the JSON artifact (and the CSV emitter)."""
    target, drafter = get_pair()
    prompts = bench_prompts(n_prompts)
    rows = []
    for strategy in strategies:
        for k in ks:
            if strategy in ("daliri", "single") and k != ks[-1]:
                continue
            rows.append(_measure(target, drafter, prompts, strategy, k,
                                 backend=backend, max_new=max_new))
    return rows


def run(fast: bool = False):
    rows = collect(ks=(8,) if fast else KS)
    out = {}
    for r in rows:
        emit(f"table1_iid_{r['strategy']}_K{r['K']}", r["us_per_prompt"],
             f"BE={r['block_efficiency']:.3f};L={L};"
             f"tok_s={r['tokens_per_s']:.1f};"
             f"host_syncs={r['host_syncs']};backend={r['backend']}")
        out[(r["strategy"], r["K"])] = r["block_efficiency"]
    return out


if __name__ == "__main__":
    run()
