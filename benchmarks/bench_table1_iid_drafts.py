"""Paper Table 1/3: multi-draft speculative decoding with i.i.d. drafts —
block efficiency (BE) per strategy and draft count K, on a trained
target/drafter pair (CPU-scale stand-in for Qwen 7B/0.5B; see DESIGN.md
§6).  Token-rate speedups are replaced by BE + verified-FLOP ratios since
this container has no accelerator wall-clock."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from benchmarks.lm_pair import bench_prompts, get_pair
from repro.specdec import SpecDecConfig, SpecDecEngine

KS = (2, 8)
STRATEGIES = ("gls", "gls_strong", "specinfer", "spectr", "daliri")
L = 4
MAX_NEW = 48
N_PROMPTS = 3


def run(fast: bool = False):
    target, drafter = get_pair()
    prompts = bench_prompts(N_PROMPTS)
    ks = (8,) if fast else KS
    rows = {}
    for strategy in STRATEGIES:
        for k in ks:
            if strategy == "daliri" and k != ks[-1]:
                continue
            kk = 1 if strategy == "daliri" else k
            eng = SpecDecEngine(
                target, [drafter],
                SpecDecConfig(num_drafts=kk, draft_len=L, strategy=strategy,
                              top_k=50, max_new_tokens=MAX_NEW))
            t0 = time.perf_counter()
            stats = [eng.generate(jax.random.PRNGKey(100 + i), p)
                     for i, p in enumerate(prompts)]
            dt_us = (time.perf_counter() - t0) * 1e6 / len(prompts)
            be = float(np.mean([s.block_efficiency for s in stats]))
            rows[(strategy, kk)] = be
            emit(f"table1_iid_{strategy}_K{kk}", dt_us, f"BE={be:.3f};L={L}")
    return rows


if __name__ == "__main__":
    run()
