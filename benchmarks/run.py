"""Benchmark orchestrator: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (harness contract).

Set REPRO_BENCH_FAST=0 for the full (slower) configurations.

``--quick`` runs the spec-dec serving benchmark, the open-loop
tail-latency benchmark, the batched Wyner–Ziv pipeline benchmark, and
the kernel-roofline microbench, and writes their merged JSON payload
(block efficiency + tokens/s for gls vs specinfer vs spectr at K in
{2, 8}, verifier-backend host-sync deltas, batched-vs-sequential
scheduler tokens/s, quant-vs-f32 serving deltas, per-strategy
race-dispatch counts, the ``open_loop`` rows — p50/p99 TTFT and ITL
for FIFO-contiguous vs paged-v2, paged-vs-contiguous bit-identity,
the paging/rotation tokens-per-s ratios the nightly gates read — the
``chaos`` rows: survivor bit-identity, zero-wedged, and metrics-
consistency under >= 5%-per-class deterministic fault injection plus
the degradation-ladder walk (DESIGN.md §13) — the
``wz_pipeline`` rows — samples/s for loop vs xla vs pallas, xla↔pallas
equality, Prop.-4 match bound — and the ``roofline_kernels`` rows with
bytes-moved / achieved-GB/s / %-of-memory-peak per coupling kernel) to
BENCH_specdec.json — the artifact CI archives so the perf trajectory
is tracked per commit.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

FAST = os.environ.get("REPRO_BENCH_FAST", "1") != "0"


def quick(out_path: str) -> None:
    from benchmarks import (
        bench_chaos,
        bench_open_loop,
        bench_roofline,
        bench_serving_backends,
        bench_wz_pipeline,
    )
    payload = bench_serving_backends.run(fast=True)
    payload["open_loop"] = bench_open_loop.run(fast=True)
    payload["wz_pipeline"] = bench_wz_pipeline.run(fast=True)
    payload["roofline_kernels"] = bench_roofline.run(fast=True)["kernels"]
    payload["chaos"] = bench_chaos.run(fast=True)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {out_path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="spec-dec serving benchmark only; write "
                         "BENCH_specdec.json")
    ap.add_argument("--out", default="BENCH_specdec.json",
                    help="JSON artifact path for --quick")
    args = ap.parse_args()
    if args.quick:
        quick(args.out)
        return

    from benchmarks import (
        bench_ablation_draft_len,
        bench_open_loop,
        bench_fig2_gaussian,
        bench_fig4_mnist,
        bench_fig6_toy_acceptance,
        bench_roofline,
        bench_serving_backends,
        bench_table1_iid_drafts,
        bench_table2_diverse_drafts,
        bench_wz_pipeline,
    )
    from benchmarks import bench_chaos
    suites = [
        ("fig6", bench_fig6_toy_acceptance),
        ("chaos", bench_chaos),
        ("table1", bench_table1_iid_drafts),
        ("table2", bench_table2_diverse_drafts),
        ("serving", bench_serving_backends),
        ("fig2", bench_fig2_gaussian),
        ("fig4", bench_fig4_mnist),
        ("wz_pipeline", bench_wz_pipeline),
        ("open_loop", bench_open_loop),
        ("ablation_L", bench_ablation_draft_len),
        ("roofline", bench_roofline),
    ]
    failures = []
    for name, mod in suites:
        try:
            if "fast" in mod.run.__code__.co_varnames:
                mod.run(fast=FAST)
            else:
                mod.run()
        except Exception:
            failures.append(name)
            print(f"{name}_FAILED,0.0,{traceback.format_exc(limit=1)!r}")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
