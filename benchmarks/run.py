"""Benchmark orchestrator: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (harness contract).

Set REPRO_BENCH_FAST=0 for the full (slower) configurations.
"""

from __future__ import annotations

import os
import sys
import traceback

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

FAST = os.environ.get("REPRO_BENCH_FAST", "1") != "0"


def main() -> None:
    from benchmarks import (
        bench_ablation_draft_len,
        bench_fig2_gaussian,
        bench_fig4_mnist,
        bench_fig6_toy_acceptance,
        bench_roofline,
        bench_table1_iid_drafts,
        bench_table2_diverse_drafts,
    )
    suites = [
        ("fig6", bench_fig6_toy_acceptance),
        ("table1", bench_table1_iid_drafts),
        ("table2", bench_table2_diverse_drafts),
        ("fig2", bench_fig2_gaussian),
        ("fig4", bench_fig4_mnist),
        ("ablation_L", bench_ablation_draft_len),
        ("roofline", bench_roofline),
    ]
    failures = []
    for name, mod in suites:
        try:
            if "fast" in mod.run.__code__.co_varnames:
                mod.run(fast=FAST)
            else:
                mod.run()
        except Exception:
            failures.append(name)
            print(f"{name}_FAILED,0.0,{traceback.format_exc(limit=1)!r}")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
