"""Chaos serving benchmark: deterministic fault injection over an
open-loop trace, gating the recovery story (DESIGN.md §13).

The serving stack's correctness claim is bit-identity: per-request
randomness is (uid, blocks)-keyed, so every execution mode — and, with
this PR, every fault-recovery path — must emit the same tokens.  This
bench injects every fault class at >= 5% per advancing request per
round (pool exhaustion, arena OOM, kernel-dispatch death, NaN-poisoned
logits, watchdog-tripping slow rounds) into a Poisson open-loop trace
served by the full stack (kv_fused + paged arena + v2 policy), for all
six coupling strategies, and gates:

  * ``survivors_bit_identical`` — every request that completes under
    chaos emits tokens bitwise equal to the fault-free reference run.
    Replay is exact because a discarded round never advanced
    ``blocks``: the retry re-derives the same randomness sheet, and
    re-prefilled KV is bitwise equal to the decode-built KV it lost.
  * ``zero_wedged`` — the drain loop terminates with nothing stuck in
    the queue or the live set: every request either completes or is
    quarantined with a recorded error.
  * ``metrics_consistent`` — ``retries == faults_total`` and
    ``completed + quarantined == submitted`` per strategy: every fault
    is counted exactly once and every request is accounted for.
  * ``all_kinds_fired`` — the seed actually exercised all five classes
    (a chaos bench that injects nothing gates nothing).
  * ``pools_clean`` — after the drain both arenas scrub: zero leaked
    slots, zero leaked pages, zero live suspend handles.

A separate ladder scenario hammers one server with kernel-dispatch
faults at ``degrade_after=1`` and gates that the server walks the
degradation ladder (kv_fused -> kv -> reprefill), keeps serving, and
STILL matches the fault-free reference bitwise — mid-serve mode
transitions are token-invisible, the same §7/§8 claim the fault layer
leans on.

The payload rides in BENCH_specdec.json under ``chaos``; CI gates the
five booleans on every nightly run.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from benchmarks.lm_pair import bench_prompts, get_pair
from repro.serving import FAULT_KINDS, FaultPlan
from repro.specdec import CachedSpecDecEngine, SpecDecConfig, SpecDecServer

L = 3
PAGE = 8
BATCH = 3
N_REQUESTS = 9
MAX_NEW = 10
MEAN_GAP_S = 0.05
RETRY_BUDGET = 3
# Generous on a shared CPU: a genuine (non-injected) trip is harmless —
# the round replays bit-identically — but each one costs a replay.
TIMEOUT_MS = 800.0
SLOW_MS = 1200.0
RATE = 0.05             # >= 5% per fault class (the ISSUE's floor)
STEP_CAP = 400          # wedge detector: a drain must finish well under

STRATEGIES = ("gls", "gls_strong", "specinfer", "spectr", "single",
              "daliri")


def _trace(seed: int = 29):
    """Poisson arrivals, Pareto prompt lengths — the open-loop shape of
    bench_open_loop at chaos-budget scale."""
    rng = np.random.default_rng(seed)
    arrive = np.cumsum(rng.exponential(MEAN_GAP_S, size=N_REQUESTS))
    lens = np.minimum(3 + (rng.pareto(2.0, size=N_REQUESTS) * 6).astype(int),
                      24)
    base = bench_prompts(N_REQUESTS, length=int(lens.max()) + 1)
    prompts = [p[:int(m)] for p, m in zip(base, lens)]
    min_buf = max(len(p) for p in prompts) + MAX_NEW + L + 2
    return arrive, prompts, min_buf


def _engine(pair, strategy: str, min_buf: int):
    target, drafter = pair
    k = 1 if strategy in ("single", "daliri") else 2
    sd = SpecDecConfig(num_drafts=k, draft_len=L, strategy=strategy,
                       top_k=0, paged=True, page_size=PAGE)
    # Page budget sized for the full live set plus detached-handle
    # slack: injected pool_exhausted displaces; REAL exhaustion is
    # bench_open_loop's subject, not this one's.
    budget = (BATCH + 1) * k * -(-min_buf // PAGE)
    return CachedSpecDecEngine(target, drafter, sd, pool_slots=BATCH,
                               pool_pages=budget)


def _make(eng, min_buf: int, **fault_kw):
    return SpecDecServer(eng, max_batch=BATCH, cache_mode="kv_fused",
                         policy="v2", min_buf_len=min_buf, **fault_kw)


def _drive(srv, prompts, arrive, key):
    """Open-loop drive with a wedge detector: the step cap bounds the
    drain, and anything still queued/live past it is wedged."""
    done, steps, i = [], 0, 0
    t0 = time.perf_counter()
    while i < len(prompts) or srv.queue or srv.live:
        now = time.perf_counter() - t0
        while i < len(prompts) and arrive[i] <= now:
            srv.submit(prompts[i], max_new=MAX_NEW)
            i += 1
        if not (srv.queue or srv.live):
            time.sleep(min(arrive[i] - now, 0.005))
            continue
        done.extend(srv.step(key))
        steps += 1
        if steps > STEP_CAP:
            break
    return done, bool(srv.queue or srv.live)


def _warm(eng, prompts, min_buf, key):
    """Off-clock compile pass over the trace's own buckets."""
    warm = _make(eng, min_buf)
    for p in prompts[:BATCH]:
        warm.submit(p, max_new=MAX_NEW)
    warm.run(key)
    assert eng.pool.buf_len == min_buf, \
        "warm pass grew the pinned buffer — bit-identity would break"


def _scrub_clean(eng) -> bool:
    """Leak check: ``scrub`` asserts every slot and every page is free
    (a leaked suspend handle or an unreleased session trips it)."""
    try:
        eng.pool.scrub()
        return True
    except AssertionError:
        return False


def collect() -> dict:
    pair = get_pair()
    arrive, prompts, min_buf = _trace()
    key = jax.random.PRNGKey(23)
    plan = FaultPlan.uniform(RATE, seed=3, slow_ms=SLOW_MS)
    payload = {"n_requests": N_REQUESTS, "fault_rate": RATE,
               "retry_budget": RETRY_BUDGET, "strategies": {}}
    kinds_fired: dict = {}
    bit_identical = zero_wedged = consistent = pools_clean = True
    ref_outputs = {}
    for strategy in STRATEGIES:
        eng = _engine(pair, strategy, min_buf)
        _warm(eng, prompts, min_buf, key)
        # Fault-free reference: unguarded server, same uids/prompts.
        ref, ref_wedged = _drive(_make(eng, min_buf), prompts, arrive, key)
        ref_out = {r.uid: list(r.output) for r in ref}
        ref_outputs[strategy] = ref_out
        zero_wedged &= not ref_wedged
        # Chaos run on the SAME engine (pool verified clean between).
        srv = _make(eng, min_buf, fault_plan=plan,
                    retry_budget=RETRY_BUDGET, round_timeout_ms=TIMEOUT_MS)
        done, wedged = _drive(srv, prompts, arrive, key)
        m = srv.metrics
        survivors = {r.uid: list(r.output) for r in done}
        s_bit = all(survivors[u] == ref_out[u] for u in survivors)
        s_consistent = (m.retries == m.faults_total
                        and m.completed + m.quarantined == N_REQUESTS
                        and m.quarantined == len(srv.failed))
        s_clean = _scrub_clean(eng)
        bit_identical &= s_bit
        zero_wedged &= not wedged
        consistent &= s_consistent
        pools_clean &= s_clean
        for k_, v in m.faults.items():
            kinds_fired[k_] = kinds_fired.get(k_, 0) + v
        payload["strategies"][strategy] = {
            "completed": m.completed, "quarantined": m.quarantined,
            "faults": dict(m.faults), "retries": m.retries,
            "watchdog_trips": m.watchdog_trips,
            "watchdog_accepts": m.watchdog_accepts,
            "bit_identical": s_bit, "wedged": wedged,
            "consistent": s_consistent, "pool_clean": s_clean,
        }
    payload["faults_by_kind"] = kinds_fired
    payload["all_kinds_fired"] = all(kinds_fired.get(k_, 0) > 0
                                     for k_ in FAULT_KINDS)
    payload["survivors_bit_identical"] = bit_identical
    payload["zero_wedged"] = zero_wedged
    payload["metrics_consistent"] = consistent
    payload["pools_clean"] = pools_clean
    payload["ladder"] = _ladder_scenario(pair, prompts, arrive, min_buf,
                                         key, ref_outputs["gls"])
    return payload


def _ladder_scenario(pair, prompts, arrive, min_buf, key, ref_out) -> dict:
    """Hammer one server with kernel-dispatch faults at
    ``degrade_after=1``: it must walk kv_fused -> kv -> reprefill,
    finish the trace, and still match the fault-free reference
    bitwise."""
    eng = _engine(pair, "gls", min_buf)
    _warm(eng, prompts, min_buf, key)
    plan = FaultPlan(seed=5, kernel_dispatch=0.35)
    srv = _make(eng, min_buf, fault_plan=plan, retry_budget=6,
                degrade_after=1)
    done, wedged = _drive(srv, prompts, arrive, key)
    m = srv.metrics
    survivors = {r.uid: list(r.output) for r in done}
    return {
        "degradations": [d["step"] for d in m.degradations],
        "final_cache_mode": srv.cache_mode,
        "faults": dict(m.faults),
        "completed": m.completed,
        "quarantined": m.quarantined,
        "wedged": wedged,
        "walked_ladder": len(m.degradations) >= 2
        and srv.cache_mode == "reprefill",
        "bit_identical": all(survivors[u] == ref_out[u]
                             for u in survivors),
    }


def run(fast: bool = False) -> dict:
    payload = collect()
    for name, s in payload["strategies"].items():
        emit(f"chaos_{name}", 0.0,
             f"completed={s['completed']}/{N_REQUESTS} "
             f"faults={sum(s['faults'].values())} retries={s['retries']} "
             f"quarantined={s['quarantined']} "
             f"bit_identical={s['bit_identical']}")
    lad = payload["ladder"]
    emit("chaos_ladder", 0.0,
         f"degradations={lad['degradations']} "
         f"final={lad['final_cache_mode']} "
         f"bit_identical={lad['bit_identical']}")
    emit("chaos_summary", 0.0,
         f"bit_identical={payload['survivors_bit_identical']} "
         f"zero_wedged={payload['zero_wedged']} "
         f"consistent={payload['metrics_consistent']} "
         f"all_kinds={payload['all_kinds_fired']} "
         f"pools_clean={payload['pools_clean']}")
    return payload


if __name__ == "__main__":
    run(fast=True)
