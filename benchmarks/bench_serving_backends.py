"""Serving-path benchmark: fused verification backends, the batched
scheduler, and bursty admission.

Three comparisons the serving refactor is accountable for:

  * verifier backends — "legacy" (per-token host loop, 2 syncs/token) vs
    "xla" (one jitted block) vs "pallas" (block race through the
    kernels/gls_race row kernel): tokens/s and verification host-sync
    counts on the same trained pair;
  * scheduler paths — sequential (R target forwards per round, full-
    prefix re-score) vs batched (ONE (R*K, T) re-score forward per
    round) vs kv (persistent KV caches in a multi-request slot pool —
    one drafter decode sweep plus ONE stacked verify_step per round, no
    re-prefill) vs kv_fused (the whole round as ONE jitted device
    program, DESIGN.md §8 — 0 draft syncs, 1 host sync per round):
    tokens/s at R=4 live requests, forwards per round, sync counts, and
    output-equality checks (all paths must be bit-identical to the
    sequential reference mode).  CI gates on
    ``kv_fused_speedup_vs_kv >= 1`` — a fused round slower than the
    host-driven round is a regression;
  * admission paths (DESIGN.md §9) — a bursty wave of queued requests
    with MIXED prompt lengths admitted ``per_request`` (2 host-driven
    prefill dispatches per request, one jit shape per observed prompt
    length) vs ``bucketed`` (prompts bucket into powers of two and
    prefill straight into pool slots, one stacked dispatch per bucket
    per model, overlapped with the running kv_fused round): per-request
    ``ttft_ms``, mean-TTFT improvement, prefill dispatch counts, and a
    bit-identity check.  Both runs are measured against a warmed engine
    whose warm corpus uses DIFFERENT prompt lengths — the bucketed
    path's compile set is the bucket set so it arrives warm, while the
    per-request path re-compiles per fresh length, which is exactly the
    production TTFT story this bench exists to track.

Two §11 additions ride along in the payload:

  * ``quant`` — kv_fused tokens/s with f32 arenas vs the int8 KV arena
    + W8A8 verify path, and per-strategy acceptance-rate deltas across
    all six strategies (the quant ship gate: CI fails on a delta beyond
    statistical tolerance, NOT on logit drift);
  * ``race_dispatches`` — trace-time race-kernel dispatch counts per
    fused round, per strategy (kernels/gls_race/ops.py counters).

``collect()`` returns the JSON payload CI archives as BENCH_specdec.json.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.bench_table1_iid_drafts import collect as table1_collect
from benchmarks.common import emit
from benchmarks.lm_pair import bench_prompts, get_pair
from repro.specdec import (
    RACE_STRATEGIES,
    RS_STRATEGIES,
    CachedSpecDecEngine,
    SpecDecConfig,
    SpecDecEngine,
    SpecDecServer,
)

L = 4
MAX_NEW = 32
SCHED_BATCH = 4   # R: live requests per round in the scheduler bench

# Bursty-admission scenario: >= 8 queued requests, mixed prompt lengths
# straddling the admission buckets.  Warm lengths deliberately differ
# from measured lengths while hitting the same buckets.
ADMIT_BATCH = 8
ADMIT_LENS_WARM = (5, 23, 14, 37, 9, 18, 29, 47, 7, 26, 12, 41)
ADMIT_LENS_MEAS = (6, 24, 15, 38, 10, 19, 30, 46, 8, 27, 13, 40)


def _mixed_prompts(lens):
    base = bench_prompts(len(lens), length=max(lens) + 1)
    return [p[:n] for p, n in zip(base, lens)]


def _bench_admission(target, drafter, *, max_new=MAX_NEW):
    """Bursty-admission TTFT: per_request vs bucketed admission under
    cache_mode="kv_fused".  Returns per-request ttft_ms, means, prefill
    dispatch counts, and the bit-identity verdict."""
    sd = SpecDecConfig(num_drafts=4, draft_len=L, strategy="gls",
                       top_k=50, max_new_tokens=max_new)
    out = {}
    outputs = {}
    for admission in ("per_request", "bucketed"):
        eng = CachedSpecDecEngine(target, drafter, sd,
                                  pool_slots=ADMIT_BATCH)

        def serve(corpus):
            srv = SpecDecServer(eng, max_batch=ADMIT_BATCH,
                                cache_mode="kv_fused", admission=admission)
            for p in corpus:
                srv.submit(p, max_new=max_new)
            done = srv.run(jax.random.PRNGKey(11))
            return srv, done

        # Warm pass: compiles the fused round and this policy's prefill
        # shapes for the WARM lengths; the measured lengths are fresh,
        # so per_request pays its per-length compiles here and bucketed
        # does not (its shapes are the bucket set).
        serve(_mixed_prompts(ADMIT_LENS_WARM))
        pd0 = eng.num_prefill_dispatches
        srv, done = serve(_mixed_prompts(ADMIT_LENS_MEAS))
        ttfts = {r.uid: r.ttft_ms for r in done}
        out[admission] = {
            "mean_ttft_ms": float(np.mean(list(ttfts.values()))),
            "max_ttft_ms": float(np.max(list(ttfts.values()))),
            "ttft_ms": {str(u): float(v) for u, v in sorted(ttfts.items())},
            "tokens_per_s": srv.metrics.tokens_per_s,
            "prefill_dispatches": eng.num_prefill_dispatches - pd0,
        }
        outputs[admission] = {r.uid: list(r.output) for r in done}
    out["queued_requests"] = len(ADMIT_LENS_MEAS)
    out["prompt_lens"] = list(ADMIT_LENS_MEAS)
    out["bit_identical"] = outputs["bucketed"] == outputs["per_request"]
    out["ttft_improvement"] = (
        out["per_request"]["mean_ttft_ms"]
        / max(out["bucketed"]["mean_ttft_ms"], 1e-9))
    return out


def _bench_backends(*, k=8, max_new=MAX_NEW, n_prompts=3):
    rows = []
    for backend in ("legacy", "xla", "pallas"):
        rows.extend(table1_collect(
            ks=(k,), strategies=("gls",), backend=backend,
            max_new=max_new, n_prompts=n_prompts))
    return rows


def _bench_scheduler(target, drafter, *, n_requests=8, max_new=MAX_NEW):
    corpus = bench_prompts(n_requests, length=12)
    sd = SpecDecConfig(num_drafts=4, draft_len=L, strategy="gls",
                       top_k=50, max_new_tokens=max_new)
    out = {}
    outputs = {}
    for mode in ("sequential", "batched", "kv", "kv_fused"):
        if mode in ("kv", "kv_fused"):
            eng = CachedSpecDecEngine(target, drafter, sd,
                                      pool_slots=SCHED_BATCH)
        else:
            eng = SpecDecEngine(target, [drafter], sd)

        def make_server():
            return SpecDecServer(eng, max_batch=SCHED_BATCH,
                                 batched=mode == "batched",
                                 cache_mode=mode if mode.startswith("kv")
                                 else "reprefill")

        # Warmup pass compiles this mode's forwards so the measured run
        # reports steady-state tokens/s, not jit tracing time.
        warm = make_server()
        for p in corpus[:SCHED_BATCH]:
            warm.submit(p, max_new=max_new)
        warm.run(jax.random.PRNGKey(3))

        server = make_server()
        for p in corpus:
            server.submit(p, max_new=max_new)
        done = server.run(jax.random.PRNGKey(7))
        m = server.metrics
        out[mode] = {
            "tokens_per_s": m.tokens_per_s,
            "mean_block_efficiency": m.mean_block_efficiency,
            "rounds": m.rounds,
            "target_forwards": m.target_forwards,
            "host_syncs": m.host_syncs,
            "draft_syncs": m.draft_syncs,
        }
        outputs[mode] = {r.uid: list(r.output) for r in done}
    out["live_requests"] = SCHED_BATCH
    out["bit_identical"] = {
        mode: outputs["sequential"] == outputs[mode]
        for mode in ("batched", "kv", "kv_fused")}
    out["kv_speedup_vs_reprefill"] = (
        out["kv"]["tokens_per_s"] / max(out["sequential"]["tokens_per_s"],
                                        1e-9))
    out["kv_fused_speedup_vs_kv"] = (
        out["kv_fused"]["tokens_per_s"] / max(out["kv"]["tokens_per_s"],
                                              1e-9))
    return out


def _bench_quant(target, drafter, *, n_requests=8, max_new=MAX_NEW):
    """Quantized serving (DESIGN.md §11): kv_fused tokens/s with the f32
    arenas vs the int8 KV arena + W8A8 verify path, plus the gate that
    decides whether quant ships — per-strategy acceptance-rate deltas
    (quantization moves logits by design; acceptance is the coupling
    statistic the paper cares about)."""
    corpus = bench_prompts(n_requests, length=12)
    out = {}
    for tag, quant in (("f32", False), ("int8", True)):
        sd = SpecDecConfig(num_drafts=4, draft_len=L, strategy="gls",
                           top_k=50, max_new_tokens=max_new, quant=quant)
        eng = CachedSpecDecEngine(target, drafter, sd,
                                  pool_slots=SCHED_BATCH)

        def make_server():
            return SpecDecServer(eng, max_batch=SCHED_BATCH,
                                 cache_mode="kv_fused")

        warm = make_server()
        for p in corpus[:SCHED_BATCH]:
            warm.submit(p, max_new=max_new)
        warm.run(jax.random.PRNGKey(3))
        server = make_server()
        for p in corpus:
            server.submit(p, max_new=max_new)
        server.run(jax.random.PRNGKey(7))
        out[tag] = {"tokens_per_s": server.metrics.tokens_per_s}
    out["quant_speedup"] = (out["int8"]["tokens_per_s"]
                            / max(out["f32"]["tokens_per_s"], 1e-9))

    accept = {}
    for strategy in RACE_STRATEGIES + RS_STRATEGIES:
        rates = {}
        for tag, quant in (("f32", False), ("int8", True)):
            sd = SpecDecConfig(num_drafts=4, draft_len=L,
                               strategy=strategy, top_k=50,
                               max_new_tokens=max_new, quant=quant)
            eng = CachedSpecDecEngine(target, drafter, sd, pool_slots=1)
            acc = blocks = 0
            for seed in (5, 6):   # shared keys across tags: the residual
                st = eng.generate(jax.random.PRNGKey(seed), corpus[0],
                                  max_new=max_new, fused=True)
                acc += st.accepted_drafts
                blocks += st.blocks
            rates[tag] = acc / (blocks * L)
        accept[strategy] = {**rates,
                            "delta": rates["int8"] - rates["f32"]}
    out["acceptance"] = accept
    out["max_acceptance_delta"] = float(
        max(abs(v["delta"]) for v in accept.values()))
    return out


def _race_dispatch_counts(target, drafter, *, max_new=16):
    """Per-round race-kernel dispatch structure per strategy: trace-time
    counters from kernels/gls_race/ops.py over one fused-engine
    generation (each engine retraces its own round program, so the
    counts are the round's embedded dispatches).  The pallas verifier
    backend is pinned — it is the one that routes through the race ops;
    RS strategies embed no race dispatch on any backend, which the
    empty counters document."""
    from repro.kernels.gls_race import ops
    prompt = bench_prompts(1, length=12)[0]
    counts = {}
    for strategy in RACE_STRATEGIES + RS_STRATEGIES:
        sd = SpecDecConfig(num_drafts=4, draft_len=L, strategy=strategy,
                           top_k=50, max_new_tokens=max_new,
                           verifier_backend="pallas")
        eng = CachedSpecDecEngine(target, drafter, sd, pool_slots=1)
        ops.reset_dispatch_counts()
        st = eng.generate(jax.random.PRNGKey(9), prompt,
                          max_new=max_new, fused=True)
        counts[strategy] = {"per_round": dict(ops.dispatch_counts),
                            "rounds": st.blocks}
    return counts


def collect(fast: bool = True):
    """BENCH_specdec.json payload: BE + tokens/s for gls vs specinfer vs
    spectr at K in {2, 8}, backend deltas, scheduler path deltas."""
    target, drafter = get_pair()   # trains once; later calls hit the cache
    max_new = MAX_NEW if fast else 48
    strat_rows = table1_collect(ks=(2, 8),
                                strategies=("gls", "specinfer", "spectr"),
                                max_new=max_new)
    strategies = {}
    for r in strat_rows:
        strategies.setdefault(r["strategy"], {})[f"K{r['K']}"] = {
            "block_efficiency": r["block_efficiency"],
            "tokens_per_s": r["tokens_per_s"],
        }
    return {
        "draft_len": L,
        "max_new_tokens": max_new,
        "strategies": strategies,
        "verifier_backends": _bench_backends(max_new=max_new),
        "scheduler": _bench_scheduler(target, drafter, max_new=max_new),
        "admission": _bench_admission(target, drafter, max_new=max_new),
        "quant": _bench_quant(target, drafter, max_new=max_new),
        "race_dispatches": _race_dispatch_counts(target, drafter),
    }


def run(fast: bool = False):
    payload = collect(fast=fast)
    for r in payload["verifier_backends"]:
        emit(f"serve_backend_{r['backend']}_gls_K{r['K']}",
             r["us_per_prompt"],
             f"tok_s={r['tokens_per_s']:.1f};host_syncs={r['host_syncs']};"
             f"BE={r['block_efficiency']:.3f}")
    sched = payload["scheduler"]
    for mode in ("sequential", "batched", "kv", "kv_fused"):
        m = sched[mode]
        emit(f"scheduler_{mode}", 0.0,
             f"tok_s={m['tokens_per_s']:.1f};rounds={m['rounds']};"
             f"target_forwards={m['target_forwards']};"
             f"host_syncs={m['host_syncs']};"
             f"draft_syncs={m['draft_syncs']}")
    emit("scheduler_paths_bit_identical", 0.0,
         str(sched["bit_identical"]))
    emit("scheduler_kv_speedup_vs_reprefill", 0.0,
         f"{sched['kv_speedup_vs_reprefill']:.2f}x")
    emit("scheduler_kv_fused_speedup_vs_kv", 0.0,
         f"{sched['kv_fused_speedup_vs_kv']:.2f}x")
    adm = payload["admission"]
    for pol in ("per_request", "bucketed"):
        a = adm[pol]
        emit(f"admission_{pol}", a["mean_ttft_ms"] * 1e3,
             f"mean_ttft_ms={a['mean_ttft_ms']:.1f};"
             f"max_ttft_ms={a['max_ttft_ms']:.1f};"
             f"tok_s={a['tokens_per_s']:.1f};"
             f"prefill_dispatches={a['prefill_dispatches']}")
    emit("admission_bit_identical", 0.0, str(adm["bit_identical"]))
    emit("admission_ttft_improvement", 0.0,
         f"{adm['ttft_improvement']:.2f}x")
    qn = payload["quant"]
    emit("serving_quant_kv_fused", 0.0,
         f"f32_tok_s={qn['f32']['tokens_per_s']:.1f};"
         f"int8_tok_s={qn['int8']['tokens_per_s']:.1f};"
         f"speedup={qn['quant_speedup']:.2f}x;"
         f"max_accept_delta={qn['max_acceptance_delta']:.3f}")
    for strategy, rd in payload["race_dispatches"].items():
        emit(f"race_dispatches_{strategy}", 0.0,
             f"per_round={rd['per_round']};rounds={rd['rounds']}")
    return payload


if __name__ == "__main__":
    run()
