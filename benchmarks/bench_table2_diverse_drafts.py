"""Paper Table 2/4: K=2 diverse drafters with mismatched temperatures
(target temp 2.0).  GLS supports heterogeneous drafters natively; SpecTr
is excluded (specialized to identically-distributed proposals, as in the
paper); SpecInfer's order sensitivity is exposed by swapping the drafter
temperatures."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from benchmarks.lm_pair import bench_prompts, get_pair
from repro.specdec import SpecDecConfig, SpecDecEngine

L = 5
MAX_NEW = 40
TEMP_PAIRS = ((0.5, 1.0), (1.0, 0.5), (1.0, 1.0))


def run(fast: bool = False):
    target, drafter = get_pair()
    prompts = bench_prompts(2 if fast else 3)
    pairs = TEMP_PAIRS[:2] if fast else TEMP_PAIRS
    rows = {}
    for strategy in ("gls", "specinfer"):
        for temps in pairs:
            eng = SpecDecEngine(
                target, [drafter, drafter],
                SpecDecConfig(num_drafts=2, draft_len=L, strategy=strategy,
                              target_temp=2.0, draft_temps=temps,
                              top_k=50, max_new_tokens=MAX_NEW))
            t0 = time.perf_counter()
            stats = [eng.generate(jax.random.PRNGKey(200 + i), p)
                     for i, p in enumerate(prompts)]
            dt_us = (time.perf_counter() - t0) * 1e6 / len(prompts)
            be = float(np.mean([s.block_efficiency for s in stats]))
            rows[(strategy, temps)] = be
            emit(f"table2_diverse_{strategy}_T{temps[0]}_{temps[1]}",
                 dt_us, f"BE={be:.3f};L={L};target_temp=2.0")
    return rows


if __name__ == "__main__":
    run()
