"""Distribution-layer tests on a small host-side mesh: sharding rules
produce valid specs and a reduced (arch x shape)-style lowering compiles
under pjit.  (The full production-mesh sweep lives in
repro.launch.sweep / dryrun_results.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.sharding import cache_shardings, param_spec, params_shardings
from repro.sharding.rules import cache_spec


def _mesh():
    # 1-device "production-shaped" mesh: axis semantics are exercised,
    # device count is whatever the host has.
    return make_host_mesh()


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_specs_rank_valid(arch):
    cfg = get_config(arch)
    mesh = _mesh()
    shapes = jax.eval_shape(
        lambda: registry.init_params(jax.random.PRNGKey(0), cfg))

    def check(path, leaf):
        spec = param_spec(path, leaf, cfg, mesh, train=True)
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)
        return leaf

    jax.tree_util.tree_map_with_path(check, shapes)


@pytest.mark.parametrize("arch", ["granite-8b", "mixtral-8x22b",
                                  "mamba2-370m", "recurrentgemma-2b",
                                  "whisper-small", "llama-3.2-vision-11b"])
def test_cache_specs_rank_valid(arch):
    cfg = get_config(arch)
    mesh = _mesh()
    shapes = jax.eval_shape(lambda: registry.init_cache(cfg, 16, 256))

    def check(path, leaf):
        spec = cache_spec(path, leaf, cfg, mesh)
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)
        return leaf

    jax.tree_util.tree_map_with_path(check, shapes)


def test_reduced_pjit_train_step_compiles():
    """A reduced dense config lowers + compiles with the full sharding
    pipeline on the host mesh — the same code path the 512-chip dry-run
    uses."""
    cfg = get_config("granite-8b").reduced()
    mesh = _mesh()
    from repro.optim import adam_update
    from repro.train.loop import lm_loss

    params_shape = jax.eval_shape(
        lambda: registry.init_params(jax.random.PRNGKey(0), cfg))
    p_shard = params_shardings(params_shape, cfg, mesh, train=True)
    batch = {
        "tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
        "targets": jax.ShapeDtypeStruct((4, 32), jnp.int32),
    }

    def step(params, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, batch), has_aux=True)(params)
        return loss, grads

    with mesh:
        compiled = jax.jit(step, in_shardings=(p_shard, None)).lower(
            params_shape, batch).compile()
    assert compiled.cost_analysis() is not None


def test_dryrun_sweep_artifacts_if_present():
    """If the sweep has produced artifacts, every recorded combo must have
    lowered successfully (status ok or an explicitly documented skip)."""
    import glob
    import json
    import os
    paths = glob.glob(os.path.join(os.path.dirname(__file__), "..",
                                   "dryrun_results", "sweep", "*.json"))
    if not paths:
        pytest.skip("dry-run sweep not yet executed")
    bad = []
    for p in paths:
        with open(p) as f:
            r = json.load(f)
        if r["status"] not in ("ok", "skipped"):
            bad.append((r["arch"], r["shape"], r["mesh"], r.get("error")))
    assert not bad, bad
