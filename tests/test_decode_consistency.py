"""Serving-path consistency: prefill + repeated decode_step must match the
full forward pass (teacher forcing) for every family — the invariant
speculative-decoding correctness rests on.  Also checks the multi-token
verify_step against repeated decode steps (bit-exact)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (
    ModelConfig,
    decode_step,
    forward,
    init_cache,
    init_params,
    prefill,
)

CASES = {
    "dense": ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                         num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                         vocab_size=256, dtype="float32"),
    "dense_swa": ModelConfig(name="w", family="dense", num_layers=2,
                             d_model=64, num_heads=4, num_kv_heads=2,
                             head_dim=16, d_ff=128, vocab_size=256,
                             sliding_window=8, dtype="float32"),
    "ssm": ModelConfig(name="s", family="ssm", num_layers=2, d_model=64,
                       num_heads=1, d_ff=0, vocab_size=256, ssm_state=16,
                       ssm_head_dim=32, ssm_chunk=4, dtype="float32"),
    "hybrid": ModelConfig(name="h", family="hybrid", num_layers=3,
                          d_model=64, num_heads=4, num_kv_heads=1,
                          head_dim=16, d_ff=128, vocab_size=256,
                          pattern_rec=2, local_window=8, lru_width=64,
                          dtype="float32"),
    "encdec": ModelConfig(name="e", family="encdec", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=4,
                          head_dim=16, d_ff=128, vocab_size=256,
                          encoder_layers=2, max_decoder_len=32,
                          dtype="float32"),
    "vlm": ModelConfig(name="v", family="vlm", num_layers=4, d_model=64,
                       num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                       vocab_size=256, cross_attn_period=2,
                       num_image_tokens=8, dtype="float32"),
}


def _batch(cfg, b, s):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    batch = {"tokens": jax.random.randint(k1, (b, s), 0, 100)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(k2, (b, s, cfg.d_model))
    if cfg.family == "vlm":
        batch["images"] = jax.random.normal(
            k2, (b, cfg.num_image_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("case", list(CASES))
def test_prefill_decode_matches_forward(case):
    cfg = CASES[case]
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s, n_dec = 2, 12, 6
    batch = _batch(cfg, b, s)
    full = forward(params, cfg, batch, remat=False)
    toks = batch["tokens"]
    pre = s - n_dec
    b_pre = dict(batch)
    b_pre["tokens"] = toks[:, :pre]
    cache = init_cache(cfg, b, 64)
    last, cache = prefill(params, cfg, b_pre, cache)
    errs = [float(jnp.max(jnp.abs(last - full[:, pre - 1])))]
    for i in range(pre, s):
        lg, cache = decode_step(params, cfg, toks[:, i:i + 1], cache)
        errs.append(float(jnp.max(jnp.abs(lg - full[:, i]))))
    assert max(errs) < 2e-3, (case, errs)


def test_moe_serving_self_consistency():
    """MoE train/serve capacity factors differ; the SERVING paths must be
    self-consistent: prefill(full) == prefill(part) + decode steps."""
    cfg = ModelConfig(name="m", family="moe", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=256, num_experts=4, experts_per_token=2,
                      dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 100)
    cache = init_cache(cfg, 2, 64)
    full_last, _ = prefill(params, cfg, {"tokens": toks}, cache)
    cache = init_cache(cfg, 2, 64)
    last, cache = prefill(params, cfg, {"tokens": toks[:, :6]}, cache)
    for i in range(6, 12):
        last, cache = decode_step(params, cfg, toks[:, i:i + 1], cache)
    assert float(jnp.max(jnp.abs(full_last - last))) < 2e-3


def test_verify_step_bit_exact_vs_decode():
    from repro.models.transformer import verify_step
    cfg = CASES["dense"]
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 100)
    cache = init_cache(cfg, 2, 64)
    _, c1 = prefill(params, cfg, {"tokens": toks[:, :6]}, cache)
    c2 = jax.tree.map(lambda a: a, c1)
    outs = []
    for i in range(6, 11):
        lg, c1 = decode_step(params, cfg, toks[:, i:i + 1], c1)
        outs.append(lg)
    ref = jnp.stack(outs, axis=1)
    got, c2 = verify_step(params, cfg, toks[:, 6:11], c2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
    assert int(c1["pos"]) == int(c2["pos"])
