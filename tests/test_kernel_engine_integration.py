"""Integration: the Pallas gls_race kernel computes exactly the token the
engine's GLS verifier emits (same shared uniforms, same target probs,
same active set) — proving the kernel is a drop-in for the serving
verification hot-path."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gls_race.kernel import gls_race
from repro.specdec import draft_token_from_uniforms, gls_verify


def test_kernel_token_matches_engine_verifier():
    K, N, TRIALS = 4, 512, 50
    key = jax.random.PRNGKey(0)
    for i in range(TRIALS):
        kk = jax.random.fold_in(key, i)
        ku, kp, kq, ka = jax.random.split(kk, 4)
        log_u = jnp.log(jax.random.uniform(ku, (K, N), minval=1e-30,
                                           maxval=1.0))
        p = jax.random.dirichlet(kp, jnp.ones(N), (K,))
        q = jax.random.dirichlet(kq, jnp.ones(N), (K,))
        active = jax.random.bernoulli(ka, 0.7, (K,)).at[0].set(True)
        d = draft_token_from_uniforms(log_u, p)

        res = gls_verify(log_u, d, q, active)
        log_s = jnp.log(-log_u)
        x_k, y_k = gls_race(log_s[None], jnp.log(jnp.maximum(p, 1e-37))[None],
                            jnp.log(jnp.maximum(q, 1e-37))[None],
                            active[None], tile_n=128)
        assert int(res.token) == int(y_k[0]), i
        np.testing.assert_array_equal(np.asarray(d), np.asarray(x_k[0]))
