"""Proposition 1 / 5: GLS produces exact marginals for both parties, and
the acceptance probability respects Theorem 1 (empirically)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    gls_sample_batch,
    gls_sample_heterogeneous,
    iid_draft_acceptance_upper,
    lml_bound,
)

TRIALS = 20_000


def _random_dist(seed, n):
    return jax.random.dirichlet(jax.random.PRNGKey(seed), jnp.ones(n))


@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_marginals_match(k):
    n = 12
    p = _random_dist(0, n)
    q = _random_dist(1, n)
    out = gls_sample_batch(jax.random.PRNGKey(2), p, q, k, TRIALS)
    y_hist = np.bincount(np.asarray(out.y), minlength=n) / TRIALS
    x_flat = np.asarray(out.x).ravel()
    x_hist = np.bincount(x_flat, minlength=n) / len(x_flat)
    # 3-sigma binomial tolerance.
    tol = 3.0 * np.sqrt(0.25 / TRIALS)
    assert np.abs(y_hist - np.asarray(q)).max() < tol + 0.5 / TRIALS
    assert np.abs(x_hist - np.asarray(p)).max() < tol + 0.5 / TRIALS


def test_acceptance_monotone_in_k():
    n = 10
    p = _random_dist(3, n)
    q = _random_dist(4, n)
    rates = []
    for k in (1, 2, 4, 8, 16):
        out = gls_sample_batch(jax.random.PRNGKey(5), p, q, k, TRIALS)
        rates.append(float(jnp.mean(out.accept)))
    assert all(b >= a - 0.02 for a, b in zip(rates, rates[1:])), rates


def test_acceptance_between_bounds():
    n = 10
    for seed in range(5):
        p = _random_dist(10 + seed, n)
        q = _random_dist(20 + seed, n)
        for k in (1, 2, 4):
            out = gls_sample_batch(jax.random.PRNGKey(seed), p, q, k, TRIALS)
            acc = float(jnp.mean(out.accept))
            lo = float(lml_bound(p, q, k))
            hi = float(iid_draft_acceptance_upper(p, q, k))
            margin = 4.0 * np.sqrt(0.25 / TRIALS)
            assert acc >= lo - margin, (seed, k, acc, lo)
            assert acc <= hi + margin, (seed, k, acc, hi)


def test_heterogeneous_marginals():
    n = 8
    k = 3
    ps = jnp.stack([_random_dist(30 + i, n) for i in range(k)])
    q = _random_dist(40, n)
    keys = jax.random.split(jax.random.PRNGKey(6), TRIALS)
    out = jax.vmap(lambda kk: gls_sample_heterogeneous(kk, ps, q))(keys)
    tol = 3.0 * np.sqrt(0.25 / TRIALS)
    y_hist = np.bincount(np.asarray(out.y), minlength=n) / TRIALS
    assert np.abs(y_hist - np.asarray(q)).max() < tol
    for i in range(k):
        x_hist = np.bincount(np.asarray(out.x[:, i]), minlength=n) / TRIALS
        assert np.abs(x_hist - np.asarray(ps[i])).max() < tol, i


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 16), st.integers(1, 6), st.integers(0, 10_000))
def test_property_identical_dists_always_accept_k1plus(n, k, seed):
    """Property: when p == q, every Y is in the draft list with probability
    -> (high); in particular the race winner for K=1 coincides exactly."""
    p = _random_dist(seed, n)
    out = gls_sample_batch(jax.random.PRNGKey(seed + 1), p, p, k, 256)
    if k == 1:
        # Identical distributions + identical randomness => identical argmin.
        assert bool(jnp.all(out.accept))
    else:
        assert float(jnp.mean(out.accept)) > 0.95


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 12), st.integers(1, 4), st.integers(0, 10_000))
def test_property_lml_bound_below_upper_bound(n, k, seed):
    """Property: the LML lower bound never exceeds the i.i.d. upper bound
    (sanity of both formulas) and lies in [0, 1]."""
    p = _random_dist(seed, n)
    q = _random_dist(seed + 1, n)
    lo = float(lml_bound(p, q, k))
    hi = float(iid_draft_acceptance_upper(p, q, k))
    assert 0.0 <= lo <= hi + 1e-6 <= 1.0 + 1e-6
