"""Batched bucketed admission (DESIGN.md §9): bucket planning, the
device-side prefill write, per-slot position invalidation, dispatch
bounds, overlap-with-round deferral — and the hard contract that none
of it changes a single emitted token versus per-request admission or
the sequential reference, for all six verification strategies."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (
    CachePool,
    ModelConfig,
    init_cache,
    init_params,
    prefill,
    prefill_slots,
)
from repro.specdec import (
    STRATEGIES,
    CachedSpecDecEngine,
    SpecDecConfig,
    SpecDecEngine,
    SpecDecServer,
)
from repro.specdec.engine_cached import _bucket_plan, _max_bucket

TCFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=48,
                   num_heads=4, num_kv_heads=2, head_dim=12, d_ff=96,
                   vocab_size=32, dtype="float32")
DCFG = TCFG.replace(name="d", num_layers=1)


@pytest.fixture(scope="module")
def pair():
    return (init_params(jax.random.PRNGKey(0), TCFG),
            init_params(jax.random.PRNGKey(1), DCFG))


# Mixed lengths: in-bucket, exactly on a bucket boundary (17 tokens ->
# 16 prefilled == bucket), straddling boundaries, and one longer than
# the largest bucket the test arena admits (so it chunks).
PROMPT_LENS = (3, 17, 9, 33, 5, 16)


def _prompts(lens=PROMPT_LENS):
    rng = np.random.RandomState(0)
    return [rng.randint(1, 30, size=n).astype(np.int32) for n in lens]


# ---------------------------------------------------------------------------
# Bucket planning
# ---------------------------------------------------------------------------


def test_bucket_plan_chunking_rule():
    assert _bucket_plan(0, 64) == []
    assert _bucket_plan(1, 64) == [(0, 1, 16)]
    assert _bucket_plan(16, 64) == [(0, 16, 16)]
    assert _bucket_plan(17, 64) == [(0, 17, 32)]
    assert _bucket_plan(64, 64) == [(0, 64, 64)]
    # Longer than the largest bucket: max-bucket chunks, then remainder.
    assert _bucket_plan(150, 64) == [(0, 64, 64), (64, 64, 64),
                                     (128, 22, 32)]
    # Chunks tile the prompt exactly, in order.
    for n in (0, 1, 15, 16, 17, 63, 64, 65, 200):
        plan = _bucket_plan(n, 64)
        off = 0
        for o, ln, b in plan:
            assert o == off and 0 < ln <= b and b <= 64
            off += ln
        assert off == n


def test_max_bucket_is_pow2_within_buffer():
    assert _max_bucket(16) == 16
    assert _max_bucket(63) == 32
    assert _max_bucket(64) == 64
    assert _max_bucket(65) == 64
    # Floored for tiny test arenas (oversized chunk pads drop at T).
    assert _max_bucket(8) == 16


# ---------------------------------------------------------------------------
# Device-side prefill write
# ---------------------------------------------------------------------------


def test_prefill_slots_matches_write_prefill(pair):
    """The §9 device-write contract: a bucketed, padded, write-masked
    prefill_slots wave leaves the slot rows bit-equal to the host
    prefill + write_prefill scatter, and every other row untouched."""
    tp, _ = pair
    K, S, BUF = 2, 3, 40
    prompt = _prompts((11,))[0]
    n = len(prompt) - 1

    ref_pool = CachePool({"m": TCFG}, num_slots=S, rows_per_slot=K,
                         buf_len=BUF)
    slot = ref_pool.alloc()
    toks = jnp.broadcast_to(jnp.asarray(prompt[None, :-1]), (K, n))
    cache = init_cache(TCFG, K, BUF)
    _, cache = prefill(tp, TCFG, {"tokens": toks}, cache)
    ref_pool.write_prefill("m", slot, cache, pos=n)

    pool = CachePool({"m": TCFG}, num_slots=S, rows_per_slot=K, buf_len=BUF)
    slot_b = pool.alloc()
    assert slot_b == slot
    rows = pool.rows_of(slot)
    bucket = 16
    tok = np.zeros((S * K, bucket), np.int32)
    write = np.zeros((S * K,), bool)
    tok[rows, :n] = prompt[:-1]
    write[rows] = True
    new = prefill_slots(tp, TCFG, jnp.asarray(tok), pool.caches["m"],
                        jnp.zeros((S * K,), jnp.int32), jnp.asarray(write))
    pool.update("m", new)
    pool.set_pos(slot, n)

    other = [r for r in range(S * K) if r not in rows]
    for kk in ("k", "v"):
        a = np.asarray(ref_pool.caches["m"][kk])
        b = np.asarray(pool.caches["m"][kk])
        np.testing.assert_array_equal(a[:, rows, :, :n], b[:, rows, :, :n])
        np.testing.assert_array_equal(b[:, other], np.zeros_like(b[:, other]))
    assert pool.pos[slot] == n


def test_prefill_slots_kernel_route_allclose(pair):
    """prefill_kernel=True streams chunk attention through the
    flash-attention Pallas kernel: same caches up to reduction order."""
    tp, _ = pair
    S, K, BUF = 2, 2, 48
    prompt = _prompts((20,))[0]
    n = len(prompt) - 1
    caches = {}
    for use_kernel in (False, True):
        pool = CachePool({"m": TCFG}, num_slots=S, rows_per_slot=K,
                         buf_len=BUF)
        slot = pool.alloc()
        rows = pool.rows_of(slot)
        tok = np.zeros((S * K, 32), np.int32)
        write = np.zeros((S * K,), bool)
        tok[rows, :n] = prompt[:-1]
        write[rows] = True
        new = prefill_slots(tp, TCFG, jnp.asarray(tok), pool.caches["m"],
                            jnp.zeros((S * K,), jnp.int32),
                            jnp.asarray(write), use_kernel=use_kernel)
        caches[use_kernel] = np.asarray(new["k"])[:, rows, :, :n]
    np.testing.assert_allclose(caches[True], caches[False],
                               atol=2e-5, rtol=2e-5)


def test_per_slot_position_invalidation():
    """Satellite contract: a lifecycle write touches ONE device position
    element; it no longer throws away (and re-uploads) the whole array."""
    pool = CachePool({"m": TCFG}, num_slots=4, rows_per_slot=2, buf_len=32)
    s0 = pool.alloc()
    dev = pool.pos_device()
    pool.set_pos(s0, 7)
    assert pool._pos_dev is not None, \
        "per-slot touch must keep the device array alive"
    s1 = pool.alloc()
    assert pool._pos_dev is not None
    np.testing.assert_array_equal(np.asarray(pool.pos_device()),
                                  pool.pos.astype(np.int32))
    pool.release(s0)
    np.testing.assert_array_equal(np.asarray(pool.pos_device()),
                                  pool.pos.astype(np.int32))
    assert s1 == 1
    del dev


# ---------------------------------------------------------------------------
# Bit-identity of the full serving path
# ---------------------------------------------------------------------------


def _serve(pair, strategy, cache_mode, admission, prompts, max_new=5,
           max_batch=2):
    tp, dp = pair
    k = 1 if strategy in ("single", "daliri") else 2
    sd = SpecDecConfig(num_drafts=k, draft_len=2, strategy=strategy,
                       top_k=0)
    if cache_mode == "reprefill":
        eng = SpecDecEngine((tp, TCFG), [(dp, DCFG)], sd)
    else:
        eng = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd,
                                  pool_slots=max_batch)
    server = SpecDecServer(eng, max_batch=max_batch, cache_mode=cache_mode,
                           admission=admission)
    for p in prompts:
        server.submit(p, max_new=max_new)
    done = server.run(jax.random.PRNGKey(7))
    return {r.uid: list(r.output) for r in done}, server


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_bucketed_admission_bit_identical(pair, strategy):
    """Bucketed (and, under kv_fused, overlapped/deferred) admission
    must emit exactly the sequential reference's tokens — every
    strategy, prompts straddling bucket boundaries and longer than the
    largest bucket."""
    prompts = _prompts()
    ref, _ = _serve(pair, strategy, "reprefill", "per_request", prompts)
    for cache_mode in ("kv", "kv_fused"):
        out, _ = _serve(pair, strategy, cache_mode, "bucketed", prompts)
        assert out == ref, (strategy, cache_mode)


def test_admission_policies_agree(pair):
    """per_request and bucketed admission are interchangeable token-wise
    (the §9 bit-identity contract between the two prefill writes)."""
    prompts = _prompts()
    a, _ = _serve(pair, "gls", "kv_fused", "per_request", prompts)
    b, _ = _serve(pair, "gls", "kv_fused", "bucketed", prompts)
    assert a == b


def test_prompt_longer_than_buffer_bucket_chunks(pair):
    """A prompt longer than the largest admission bucket prefills in
    chunks and still matches the reference trace."""
    prompts = _prompts((70, 4))
    ref, _ = _serve(pair, "gls", "reprefill", "per_request", prompts,
                    max_new=4)
    out, srv = _serve(pair, "gls", "kv_fused", "bucketed", prompts,
                      max_new=4)
    assert out == ref
    # 70-token prompt: buf = 70+4+2+2 = 78 -> max bucket 64 -> 69
    # prefill tokens chunk as 64 + 5.
    assert srv.engine.num_prefill_dispatches >= 4


# ---------------------------------------------------------------------------
# Dispatch bounds and overlap scheduling
# ---------------------------------------------------------------------------


def test_admission_wave_dispatches_bounded_by_buckets(pair):
    """One admission wave of R same-bucket requests costs 2 dispatches
    (one per model), not 2R."""
    tp, dp = pair
    sd = SpecDecConfig(num_drafts=2, draft_len=2, strategy="gls", top_k=0)
    eng = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd, pool_slots=4)
    pairs = [(i, p) for i, p in enumerate(_prompts((5, 9, 12, 7)))]
    eng.admit_batch(pairs, buf_len=40)
    assert eng.num_prefill_dispatches == 2
    for uid, _ in pairs:
        eng.release(uid)

    # Two buckets (16 and 32) -> four dispatches.
    eng2 = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd, pool_slots=4)
    pairs2 = [(i, p) for i, p in enumerate(_prompts((5, 30, 12, 25)))]
    eng2.admit_batch(pairs2, buf_len=40)
    assert eng2.num_prefill_dispatches == 4


def test_overlap_defers_first_block_one_round(pair):
    """kv_fused + bucketed: a request admitted this step only prefills;
    its first tokens arrive next step (§9 join-next-round rule)."""
    tp, dp = pair
    sd = SpecDecConfig(num_drafts=2, draft_len=2, strategy="gls", top_k=0)
    eng = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd, pool_slots=2)
    server = SpecDecServer(eng, max_batch=2, cache_mode="kv_fused")
    server.submit(np.array([1, 2, 3], np.int32), max_new=4)
    key = jax.random.PRNGKey(0)
    server.step(key)
    (req,) = server.live
    assert req.output == [] and req.blocks == 0, \
        "admission round must not advance the request"
    assert server.metrics.rounds == 0
    server.step(key)
    assert len(req.output) > 0 and req.blocks == 1
    assert server.metrics.rounds == 1
