"""Cached (production) engine vs the reference recompute engine: identical
shared randomness must give identical output tokens — across all six
verification strategies and both fused verifier backends — plus the
cached path's host-sync accounting and rollback-row contracts."""

import jax
import numpy as np
import pytest

from repro.models import ModelConfig, init_params
from repro.specdec import STRATEGIES, SpecDecConfig, SpecDecEngine
from repro.specdec.engine_cached import (
    CachedSpecDecEngine,
    _select_rollback_row,
)

TCFG = ModelConfig(name="t", family="dense", num_layers=3, d_model=64,
                   num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                   vocab_size=64, dtype="float32")
DCFG = TCFG.replace(name="d", num_layers=1)


@pytest.fixture(scope="module")
def pair():
    return (init_params(jax.random.PRNGKey(0), TCFG),
            init_params(jax.random.PRNGKey(1), DCFG))


def _match_runs(pair, strategy, backend, runs=4, max_new=20):
    tp, dp = pair
    k = 1 if strategy in ("single", "daliri") else 4
    sd = SpecDecConfig(num_drafts=k, draft_len=3, strategy=strategy,
                       max_new_tokens=max_new, top_k=0,
                       verifier_backend=backend)
    ref = SpecDecEngine((tp, TCFG), [(dp, DCFG)], sd)
    fast = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd)
    prompt = np.array([1, 2, 3, 4], np.int32)
    matches = 0
    for i in range(runs):
        key = jax.random.PRNGKey(50 + i)
        o1 = ref.generate(key, prompt)
        o2 = fast.generate(key, prompt)
        matches += int(np.array_equal(o1.output, o2.output))
    return matches


@pytest.mark.parametrize("strategy", ["gls", "gls_strong"])
def test_cached_engine_matches_reference(pair, strategy):
    # fp differences between cached and recompute logits can flip a rare
    # near-tie race; demand near-perfect agreement.
    matches = _match_runs(pair, strategy, "xla")
    assert matches >= 3, f"only {matches}/4 runs matched"


@pytest.mark.parametrize("strategy", ["specinfer", "spectr", "single",
                                      "daliri"])
def test_cached_engine_matches_reference_rs(pair, strategy):
    matches = _match_runs(pair, strategy, "xla", runs=2, max_new=14)
    assert matches >= 1, f"0/2 runs matched for {strategy}"


@pytest.mark.slow
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_cached_engine_matches_reference_pallas(pair, strategy):
    """Full nightly sweep: the pallas verifier backend must agree with
    the reference engine for every strategy (interpret mode on CPU)."""
    matches = _match_runs(pair, strategy, "pallas", runs=2, max_new=14)
    assert matches >= 1, f"0/2 runs matched for {strategy}/pallas"


def test_cached_engine_be_reasonable(pair):
    tp, dp = pair
    sd = SpecDecConfig(num_drafts=8, draft_len=4, strategy="gls",
                       max_new_tokens=32, top_k=0)
    fast = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd)
    o = fast.generate(jax.random.PRNGKey(9), np.array([5, 6, 7], np.int32))
    assert 1.0 <= o.block_efficiency <= sd.draft_len + 1


def test_cached_engine_host_sync_accounting(pair):
    """DESIGN.md §7.3: with a fused backend the verification path costs
    exactly ONE device->host transfer per block (positions are tracked
    host-side; rollback row selection reuses the verifier's transfer),
    and the drafter loop costs one transfer per draft step."""
    tp, dp = pair
    sd = SpecDecConfig(num_drafts=4, draft_len=3, strategy="gls",
                       max_new_tokens=16, top_k=0, verifier_backend="xla")
    fast = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd)
    o = fast.generate(jax.random.PRNGKey(3), np.array([1, 2, 3], np.int32))
    assert o.host_syncs == o.blocks
    assert fast.num_draft_syncs == o.blocks * sd.draft_len


def test_cached_engine_multi_request_pool_matches_solo(pair):
    """Two co-resident requests in one pool emit exactly the tokens each
    would emit alone (slot isolation + per-request RNG streams)."""
    tp, dp = pair
    sd = SpecDecConfig(num_drafts=2, draft_len=2, strategy="gls", top_k=0)
    prompts = {7: np.array([1, 2, 3], np.int32),
               9: np.array([4, 5, 6, 7], np.int32)}
    max_new = 8
    buf = max(len(p) for p in prompts.values()) + max_new + 4

    def drive(engine, uids):
        out = {u: [] for u in uids}
        prefix = {u: list(prompts[u]) for u in uids}
        blocks = {u: 0 for u in uids}
        while any(len(out[u]) < max_new for u in uids):
            live = [u for u in uids if len(out[u]) < max_new]
            subs = [jax.random.fold_in(jax.random.PRNGKey(11), u * 100
                                       + blocks[u]) for u in live]
            res = engine.gen_blocks(
                subs, [np.asarray(prefix[u], np.int32) for u in live],
                buf, uids=live)
            for u, o in zip(live, res):
                out[u].extend(o.new_tokens)
                prefix[u].extend(o.new_tokens)
                blocks[u] += 1
                if len(out[u]) >= max_new:
                    engine.release(u)
        return {u: out[u][:max_new] for u in uids}

    multi = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd, pool_slots=2)
    both = drive(multi, [7, 9])
    assert multi.pool.num_free == 2
    for u in (7, 9):
        solo = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd,
                                   pool_slots=1)
        assert drive(solo, [u]) == {u: both[u]}, f"uid {u} diverged"


def test_gen_blocks_validates_prefix_tail(pair):
    tp, dp = pair
    sd = SpecDecConfig(num_drafts=2, draft_len=2, strategy="gls", top_k=0)
    eng = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd)
    prefix = np.array([1, 2, 3], np.int32)
    out = eng.gen_block(jax.random.PRNGKey(0), prefix, 16, uid=1)
    good = np.concatenate([prefix, np.asarray(out.new_tokens, np.int32)])
    with pytest.raises(AssertionError, match="pending"):
        bad = np.concatenate([good, [int(good[-1]) + 1]]).astype(np.int32)
        eng.gen_block(jax.random.PRNGKey(1), bad, 16, uid=1)


def test_heterogeneous_draft_temps_rejected(pair):
    """The cached draft sweep scores every lane at temps[0]; diverse
    temps must fail loudly instead of silently diverging from the
    reference engine's per-column path."""
    tp, dp = pair
    sd = SpecDecConfig(num_drafts=2, draft_len=2, strategy="gls",
                       draft_temps=(0.7, 1.3), top_k=0)
    with pytest.raises(AssertionError, match="homogeneous"):
        CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd)


def test_block_past_buffer_rejected(pair):
    """Arenas are non-ring: a block that would write past buf_len fails
    loudly instead of wrapping/clamping KV writes."""
    tp, dp = pair
    sd = SpecDecConfig(num_drafts=2, draft_len=2, strategy="gls", top_k=0)
    eng = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd)
    prefix = np.array([1, 2, 3, 4], np.int32)
    buf = len(prefix) + 2   # room for one block at most
    out = eng.gen_block(jax.random.PRNGKey(0), prefix, buf, uid=5)
    with pytest.raises(AssertionError, match="cache arena holds"):
        for i in range(8):
            prefix = np.concatenate(
                [prefix, np.asarray(out.new_tokens, np.int32)])
            out = eng.gen_block(jax.random.PRNGKey(1 + i), prefix, buf,
                                uid=5)


def test_select_rollback_row_contract():
    # a == 0: every row's cache agrees on the pending token — row 0.
    assert _select_rollback_row(np.array([False, False]), 0) == 0
    # a > 0: first surviving row, explicitly.
    assert _select_rollback_row(np.array([False, True, True]), 2) == 1
    # a > 0 with no survivor is a verifier/engine disagreement: loud.
    with pytest.raises(AssertionError, match="rollback invariant"):
        _select_rollback_row(np.array([False, False]), 1)
