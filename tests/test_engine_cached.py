"""Cached (production) engine vs the reference recompute engine: identical
shared randomness must give identical output tokens."""

import jax
import numpy as np
import pytest

from repro.models import ModelConfig, init_params
from repro.specdec import SpecDecConfig, SpecDecEngine
from repro.specdec.engine_cached import CachedSpecDecEngine

TCFG = ModelConfig(name="t", family="dense", num_layers=3, d_model=64,
                   num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                   vocab_size=64, dtype="float32")
DCFG = TCFG.replace(name="d", num_layers=1)


@pytest.fixture(scope="module")
def pair():
    return (init_params(jax.random.PRNGKey(0), TCFG),
            init_params(jax.random.PRNGKey(1), DCFG))


@pytest.mark.parametrize("strategy", ["gls", "gls_strong"])
def test_cached_engine_matches_reference(pair, strategy):
    tp, dp = pair
    sd = SpecDecConfig(num_drafts=4, draft_len=3, strategy=strategy,
                       max_new_tokens=20, top_k=0)
    ref = SpecDecEngine((tp, TCFG), [(dp, DCFG)], sd)
    fast = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd)
    prompt = np.array([1, 2, 3, 4], np.int32)
    matches = 0
    for i in range(4):
        key = jax.random.PRNGKey(50 + i)
        o1 = ref.generate(key, prompt)
        o2 = fast.generate(key, prompt)
        matches += int(np.array_equal(o1.output, o2.output))
    # fp differences between cached and recompute logits can flip a rare
    # near-tie race; demand near-perfect agreement.
    assert matches >= 3, f"only {matches}/4 runs matched"


def test_cached_engine_be_reasonable(pair):
    tp, dp = pair
    sd = SpecDecConfig(num_drafts=8, draft_len=4, strategy="gls",
                       max_new_tokens=32, top_k=0)
    fast = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd)
    o = fast.generate(jax.random.PRNGKey(9), np.array([5, 6, 7], np.int32))
    assert 1.0 <= o.block_efficiency <= sd.draft_len + 1
