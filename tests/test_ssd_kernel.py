"""SSD intra-chunk Pallas kernel: shape sweeps vs the jnp oracle and the
full model-path ssd_chunked (interpret=True on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_chunk.kernel import ssd_chunk
from repro.kernels.ssd_chunk.ops import ssd_chunked_kernel
from repro.kernels.ssd_chunk.ref import ssd_chunk_ref
from repro.models.mamba2 import ssd_chunked


def _inputs(seed, b, nc, q, h, p, n, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, nc, q, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, nc, q, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b_in = jax.random.normal(ks[3], (b, nc, q, n), dtype)
    c_in = jax.random.normal(ks[4], (b, nc, q, n), dtype)
    return x, dt, a, b_in, c_in


@pytest.mark.parametrize("b,nc,q,h,p,n", [
    (1, 1, 8, 1, 16, 8),
    (2, 3, 16, 4, 32, 16),
    (1, 2, 64, 2, 64, 128),   # mamba2-370m-like head tile
])
def test_ssd_chunk_matches_ref(b, nc, q, h, p, n):
    args = _inputs(q + n, b, nc, q, h, p, n)
    y, st, tot = ssd_chunk(*args)
    yr, str_, totr = ssd_chunk_ref(*args)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(tot), np.asarray(totr),
                               atol=1e-5, rtol=1e-5)


def test_ssd_chunked_kernel_matches_model_path():
    b, s, h, p, n, q = 2, 48, 4, 32, 16, 16
    x, dt, a, b_in, c_in = _inputs(7, b, s // q, q, h, p, n)
    xf = x.reshape(b, s, h, p)
    dtf = dt.reshape(b, s, h)
    bf = b_in.reshape(b, s, n)
    cf = c_in.reshape(b, s, n)
    y1, h1 = ssd_chunked(xf, dtf, a, bf, cf, q)
    y2, h2 = ssd_chunked_kernel(xf, dtf, a, bf, cf, q)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=2e-3, rtol=2e-3)


def test_ssd_chunk_ragged_seq_padding():
    """ssd_chunked_kernel pads non-multiple sequence lengths."""
    b, s, h, p, n, q = 1, 20, 2, 16, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b_in = jax.random.normal(ks[3], (b, s, n))
    c_in = jax.random.normal(ks[4], (b, s, n))
    y1, _ = ssd_chunked(x, dt, a, b_in, c_in, q)
    y2, _ = ssd_chunked_kernel(x, dt, a, b_in, c_in, q)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-3, rtol=2e-3)
