"""Fault-injected serving (DESIGN.md §13): deterministic injection,
round guards, watchdogs, retry/replay bit-identity, quarantine, and
the graceful-degradation ladder."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.models import ModelConfig, init_params
from repro.models.cache_pool import PagePoolExhausted
from repro.serving import FAULT_KINDS, FaultPlan, InvalidRequest
from repro.specdec import CachedSpecDecEngine, SpecDecConfig, SpecDecEngine
from repro.specdec.scheduler import SpecDecServer

TCFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=48,
                   num_heads=4, num_kv_heads=2, head_dim=12, d_ff=96,
                   vocab_size=32, dtype="float32")
DCFG = TCFG.replace(name="d", num_layers=1)
SD = SpecDecConfig(num_drafts=2, draft_len=2, strategy="gls", top_k=0)

PROMPTS = [np.arange(1, 1 + n, dtype=np.int32) % 31 + 1
           for n in (3, 5, 4, 6)]
MAX_NEW = 6
KEY = jax.random.PRNGKey(7)


@pytest.fixture(scope="module")
def pair():
    return (init_params(jax.random.PRNGKey(0), TCFG),
            init_params(jax.random.PRNGKey(1), DCFG))


def _min_buf(sd=SD, prompts=PROMPTS, max_new=MAX_NEW):
    return max(len(p) for p in prompts) + max_new + sd.draft_len + 2


@pytest.fixture(scope="module")
def oracle(pair):
    """Fault-free sequential reprefill reference outputs, keyed by uid."""
    tp, dp = pair
    srv = SpecDecServer(SpecDecEngine((tp, TCFG), [(dp, DCFG)], SD),
                        max_batch=2, cache_mode="reprefill",
                        min_buf_len=_min_buf())
    for p in PROMPTS:
        srv.submit(p, max_new=MAX_NEW)
    done = srv.run(KEY)
    return {r.uid: list(r.output) for r in done}


def _paged_server(pair, **kw):
    tp, dp = pair
    sdp = dataclasses.replace(SD, paged=True, page_size=8)
    eng = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sdp,
                              pool_slots=2, pool_pages=24)
    return eng, SpecDecServer(eng, max_batch=2, cache_mode="kv_fused",
                              policy="v2", min_buf_len=_min_buf(), **kw)


def _serve(srv):
    for p in PROMPTS:
        srv.submit(p, max_new=MAX_NEW)
    done = srv.run(KEY)
    return {r.uid: list(r.output) for r in done}


# ---- fault plan ------------------------------------------------------


def test_fault_plan_deterministic_keyed_draws():
    """Same plan, same coordinates, same draws — wall clock and call
    order never matter; the attempt index re-draws so a retry is not
    doomed to refault."""
    a = FaultPlan.uniform(0.3, seed=11)
    b = FaultPlan.uniform(0.3, seed=11)
    coords = [(k, uid, blk, att) for k in FAULT_KINDS
              for uid in range(8) for blk in range(8) for att in range(3)]
    draws = [a.fires(*c) for c in coords]
    assert draws == [b.fires(*c) for c in coords]
    assert any(draws) and not all(draws)
    assert len({tuple(a.fires(k, uid, blk, att)
                      for k, uid, blk, _ in coords[:64])
                for att in range(4)}) > 1, "attempt index not in the key"
    only = FaultPlan.uniform(1.0, only_uids=(3,))
    assert only.fires("oom", 3, 0) and not only.fires("oom", 4, 0)
    with pytest.raises(ValueError, match="rate"):
        FaultPlan(nan_logits=1.5)


# ---- submit validation (satellite: typed InvalidRequest) -------------


def test_submit_rejects_malformed_requests(pair):
    tp, dp = pair
    srv = SpecDecServer(SpecDecEngine((tp, TCFG), [(dp, DCFG)], SD))
    ok = np.array([1, 2, 3], np.int32)
    with pytest.raises(InvalidRequest, match="at least one token"):
        srv.submit(np.array([], np.int32), max_new=4)
    with pytest.raises(InvalidRequest, match="1-D"):
        srv.submit(np.ones((2, 2), np.int32), max_new=4)
    with pytest.raises(InvalidRequest, match="integer dtype"):
        srv.submit(np.array([1.5, 2.0]), max_new=4)
    with pytest.raises(InvalidRequest, match="max_new"):
        srv.submit(ok, max_new=0)
    with pytest.raises(InvalidRequest, match=r"\[0, 32\)"):
        srv.submit(np.array([1, 99], np.int32), max_new=4)
    with pytest.raises(InvalidRequest, match=r"\[0, 32\)"):
        srv.submit(np.array([-1, 3], np.int32), max_new=4)
    assert not srv.queue, "rejected submits must not enqueue"
    srv.submit(ok, max_new=4)
    assert len(srv.queue) == 1


# ---- on_token isolation (satellite: callback failure) ----------------


def test_on_token_callback_failure_isolated(pair, oracle):
    """A raising on_token callback fails only ITS request: the victim
    lands in server.failed with the error recorded and its slot
    released; every other request completes bit-identically."""
    tp, dp = pair
    eng = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), SD, pool_slots=2)
    srv = SpecDecServer(eng, max_batch=2, cache_mode="kv",
                        min_buf_len=_min_buf())
    streamed = []

    def cb(uid, tok):
        streamed.append((uid, tok))
        if uid == 1 and len([t for u, t in streamed if u == 1]) == 2:
            raise RuntimeError("consumer hung up")

    for p in PROMPTS:
        srv.submit(p, max_new=MAX_NEW, on_token=cb)
    done = srv.run(KEY)
    got = {r.uid: list(r.output) for r in done}
    assert set(got) == {2, 3, 4}  # uids start at 1; uid 1 failed
    assert all(got[u] == oracle[u] for u in got)
    assert [r.uid for r in srv.failed] == [1]
    assert "on_token callback raised" in srv.failed[0].error
    assert "consumer hung up" in srv.failed[0].error
    assert srv.metrics.callback_errors == 1
    assert eng.pool.num_free == eng.pool.num_slots, \
        "failed request leaked its slot"
    # Tokens streamed before the failure match the victim's record.
    assert [t for u, t in streamed if u == 1] == srv.failed[0].output[:2]


# ---- chaos replay bit-identity ---------------------------------------


def test_chaos_replay_bit_identical_paged_v2(pair, oracle):
    """The tentpole gate at test scale: heavy injection of every fault
    class into the full stack (kv_fused + paged arena + v2), survivors
    bit-identical to the fault-free reference, every fault counted."""
    plan = FaultPlan.uniform(0.15, seed=2)
    eng, srv = _paged_server(pair, fault_plan=plan, retry_budget=3)
    got = _serve(srv)
    m = srv.metrics
    assert m.faults_total > 0, "seed injected nothing — tune it"
    assert m.retries == m.faults_total
    assert m.completed + m.quarantined == len(PROMPTS)
    assert all(got[u] == oracle[u] for u in got)
    assert eng.pool.num_free == eng.pool.num_slots
    st = eng.page_state()
    assert st["free"] == st["total"], "recovery leaked pages"


def test_targeted_nan_poisoning_quarantines_victim(pair, oracle):
    """nan_logits at rate 1.0 for one uid: every retry refaults, the
    retry budget trips, the victim quarantines with a recorded error —
    and the poisoning never taints anyone else (arenas scrubbed)."""
    plan = FaultPlan(seed=0, nan_logits=1.0, only_uids=(2,))
    eng, srv = _paged_server(pair, fault_plan=plan, retry_budget=1)
    got = _serve(srv)
    assert set(got) == {1, 3, 4}  # uids start at 1; uid 2 quarantined
    assert all(got[u] == oracle[u] for u in got)
    assert srv.metrics.quarantined == 1
    assert [r.uid for r in srv.failed] == [2]
    assert srv.failed[0].error.startswith("quarantined:")
    assert srv.failed[0].retries == 2  # budget 1 → quarantined on fault 2
    assert srv.metrics.faults.get("nan_logits", 0) >= 2, \
        "poisoned outcomes must be caught and attributed to injection"
    st = eng.page_state()
    assert st["free"] == st["total"]


def test_real_pool_exhaustion_converts_to_displacement(pair, oracle):
    """Satellite: a REAL PagePoolExhausted raised mid-trace under a
    guarded v2 server converts into displacement (suspend/evict +
    requeue) instead of killing the trace, and the displaced requests
    finish bit-identically on re-admission."""
    eng, srv = _paged_server(pair, retry_budget=2)
    for p in PROMPTS:
        srv.submit(p, max_new=MAX_NEW)
    # The pool exists only after the first admission — run one round,
    # then make the NEXT reserve raise a real exhaustion mid-trace.
    done = list(srv.step(KEY))
    state = {"calls": 0, "raised": False}
    real_reserve = eng.pool.reserve

    def flaky_reserve(*a, **kw):
        state["calls"] += 1
        if state["calls"] == 2 and not state["raised"]:
            state["raised"] = True
            raise PagePoolExhausted("injected real exhaustion")
        return real_reserve(*a, **kw)

    eng.pool.reserve = flaky_reserve
    done.extend(srv.run(KEY))
    got = {r.uid: list(r.output) for r in done}
    assert state["raised"], "trace never reached the flaky reserve"
    assert set(got) == set(oracle)
    assert got == oracle
    assert srv.metrics.faults == {"pool_exhausted": 1}
    assert srv.metrics.retries == 1
    st = eng.page_state()
    assert st["free"] == st["total"]


def test_unguarded_server_stays_loud(pair):
    """Without any fault knob the recovery layer must stay out of the
    way: a PagePoolExhausted propagates to the caller exactly as
    before (the §12 loud-exhaustion contract)."""
    eng, srv = _paged_server(pair)
    assert not srv.guarded
    srv.submit(PROMPTS[0], max_new=MAX_NEW)
    srv.step(KEY)  # first round creates the pool

    def always_raise(*a, **kw):
        raise PagePoolExhausted("budget exceeded")

    eng.pool.reserve = always_raise
    with pytest.raises(PagePoolExhausted):
        srv.run(KEY)


# ---- watchdog --------------------------------------------------------


def test_watchdog_trips_replays_then_accepts(pair, oracle):
    """An unreachable round budget trips the watchdog every round; the
    first trip discards and replays (bit-identically), and once
    consecutive trips exceed the retry budget the accept valve takes
    the late-but-valid round instead of livelocking."""
    eng, srv = _paged_server(pair, round_timeout_ms=0.01, retry_budget=0)
    got = _serve(srv)
    m = srv.metrics
    assert got == oracle
    assert m.watchdog_trips > 0
    assert m.watchdog_accepts > 0
    assert m.faults.get("watchdog", 0) == m.retries
    st = eng.page_state()
    assert st["free"] == st["total"]


# ---- degradation ladder ----------------------------------------------


def test_degradation_ladder_walks_to_reference(pair, oracle):
    """Repeated kernel-dispatch faults at degrade_after=1 walk the
    ladder kv_fused -> kv -> reprefill; the server keeps serving on the
    reference path and the tokens never change — mid-serve mode
    transitions are token-invisible."""
    plan = FaultPlan(seed=4, kernel_dispatch=0.5)
    eng, srv = _paged_server(pair, fault_plan=plan, retry_budget=6,
                             degrade_after=1)
    got = _serve(srv)
    m = srv.metrics
    steps = [d["step"] for d in m.degradations]
    assert steps[:2] == ["cache:kv_fused->kv", "cache:kv->reprefill"]
    assert srv.cache_mode == "reprefill"
    assert m.faults.get("kernel_dispatch", 0) >= 2
    assert all(got[u] == oracle[u] for u in got)
    assert m.completed + m.quarantined == len(PROMPTS)
    st = eng.page_state()
    assert st["free"] == st["total"]
