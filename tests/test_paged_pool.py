"""Paged KV arena (DESIGN.md §12): page lifecycle, fixed-budget
exhaustion, table-widening buffer growth, kernel-level page-table
indirection, and paged-vs-contiguous bit-identity of the slot model
ops — the contiguous arena is the oracle throughout."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    decode_attention_op,
    decode_attention_paged_op,
    flash_attention_op,
    flash_attention_paged_op,
    gather_kv_pages,
)
from repro.models import (
    CachePool,
    ModelConfig,
    PagePoolExhausted,
    PagedCachePool,
    decode_step_slots,
    decode_step_slots_paged,
    init_cache,
    init_params,
    prefill,
    verify_step_slots,
    verify_step_slots_paged,
)

CFG = ModelConfig(name="p", family="dense", num_layers=2, d_model=32,
                  num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                  vocab_size=32, dtype="float32")


def make_paged(slots=2, rows=2, buf=16, page=4, num_pages=None):
    return PagedCachePool({"m": CFG}, num_slots=slots, rows_per_slot=rows,
                          buf_len=buf, page_size=page, num_pages=num_pages)


# ---- page lifecycle ---------------------------------------------------

def test_detach_attach_round_trips_chains_and_content():
    # Suspend/resume primitives (DESIGN.md §12): detach parks a slot's
    # chains in a handle (pages stay resident, table rows zero, slot
    # free), attach re-binds them to ANY free slot with the bytes and
    # position intact — a host table rewrite, zero recompute.
    pool = make_paged(num_pages=16)
    s0 = pool.alloc()
    pool.reserve(s0, 7)
    pool.set_pos(s0, 7)
    rows = pool.rows_of(s0)
    chains_before = pool.page_table[rows].copy()
    held = pool.held_pages(s0)
    free_before = pool.free_pages

    handle = pool.detach(s0)
    # Slot freed, table rows zeroed — but the pages did NOT return to
    # the free heap: the handle owns them.
    assert (pool.page_table[rows] == 0).all()
    assert pool.free_pages == free_before

    # Re-attach to a DIFFERENT slot: same chains, same pos.
    s1 = pool.alloc()
    assert s1 == s0          # detach freed the slot (lowest-free-first)
    s2 = pool.alloc()
    assert s2 != s0
    pool.attach(s2, handle)
    np.testing.assert_array_equal(
        pool.page_table[pool.rows_of(s2)], chains_before)
    assert pool.pos[s2] == 7
    assert pool.held_pages(s2) == held

    # Dropping a handle (strip demotion) returns its pages to the heap.
    h2 = pool.detach(s2)
    pool.release_handle(h2)
    assert pool.free_pages == free_before + held
    assert h2["chain_len"] == 0

def test_reserve_is_lowest_free_page_first_in_row_lockstep():
    pool = make_paged(num_pages=16)
    s = pool.alloc()
    pool.reserve(s, 5)                       # ceil(5/4)=2 pages x 2 rows
    assert pool.held_pages(s) == 4
    assert pool.free_pages == 12
    rows = pool.rows_of(s)
    # Deterministic allocation: lowest physical pages first, rows in
    # lockstep (chains advance together because positions are shared).
    assert sorted(pool.page_table[rows, :2].reshape(-1).tolist()) == \
        [1, 2, 3, 4]
    assert (pool.page_table[rows, 2:] == 0).all()
    pool.reserve(s, 5)                       # idempotent: already covered
    assert pool.held_pages(s) == 4


def test_release_returns_pages_and_zeroes_table_rows():
    pool = make_paged(num_pages=16)
    a, b = pool.alloc(), pool.alloc()
    pool.reserve(a, 8)
    pool.reserve(b, 4)
    held_a = pool.held_pages(a)
    pool.release(a)
    assert (pool.page_table[pool.rows_of(a)] == 0).all()
    assert pool.free_pages == 16 - pool.held_pages(b)
    # released pages are reallocated lowest-first: slot a held the
    # lowest physical pages, so the next reservation reuses them.
    c = pool.alloc()
    pool.reserve(c, 8)
    assert pool.held_pages(c) == held_a
    assert pool.page_table[pool.rows_of(c), 0].min() == 1


def test_fixed_budget_exhaustion_raises_without_partial_state():
    pool = make_paged(num_pages=4)           # room for 4 pages total
    s = pool.alloc()
    pool.reserve(s, 8)                       # 2 pages x 2 rows = all 4
    table_before = pool.page_table.copy()
    with pytest.raises(PagePoolExhausted):
        pool.reserve(s, 9)                   # needs a 3rd page per row
    np.testing.assert_array_equal(pool.page_table, table_before)
    assert pool.free_pages == 0
    pool.release(s)
    assert pool.free_pages == 4


def test_auto_grow_doubles_storage_with_stable_page_indices():
    pool = make_paged(buf=16, page=4, num_pages=None)
    total0 = pool.num_pages
    s = pool.alloc()
    pool.reserve(s, 16)
    rows = pool.rows_of(s)
    chains = pool.page_table[rows].copy()
    pool.ensure_buf(2 * pool.buf_len)        # widening only
    t = pool.alloc()
    pool.reserve(t, 32)                      # overflows the initial pool
    assert pool.num_pages > total0
    # Growth never remaps: the first slot's chain entries are unchanged.
    np.testing.assert_array_equal(pool.page_table[rows, :chains.shape[1]],
                                  chains)


def test_ensure_buf_is_table_widening_not_storage_copy():
    pool = make_paged(num_pages=8)
    leaf_before = pool.pages["m"]["k"]
    n_lp0 = pool.page_table.shape[1]
    pool.ensure_buf(32)
    assert pool.buf_len == 32
    assert pool.page_table.shape[1] > n_lp0
    assert pool.pages["m"]["k"] is leaf_before   # no whole-pool regrowth
    pool.ensure_buf(16)                          # monotonic: no shrink
    assert pool.buf_len == 32


def test_contiguous_caches_attr_fails_loudly():
    pool = make_paged()
    with pytest.raises(AttributeError):
        pool.caches["m"]


# ---- kernel-level page-table indirection ------------------------------

def _random_pages(key, p=6, hkv=2, page=4, d=8):
    pages = jax.random.normal(key, (p, hkv, page, d), jnp.float32)
    return pages.at[0].set(0.0)              # physical page 0 is the zero page


def test_gather_kv_pages_matches_manual_chain():
    pages = _random_pages(jax.random.PRNGKey(0))
    table = jnp.array([[1, 3, 0], [2, 4, 5]], jnp.int32)
    got = gather_kv_pages(pages, table, 10)
    pg = np.asarray(pages)
    for b, chain in enumerate(np.asarray(table)):
        want = np.concatenate([pg[p] for p in chain], axis=1)[:, :10]
        np.testing.assert_array_equal(np.asarray(got[b]), want)
    # unmapped entries resolve to zeros
    assert not np.asarray(got[0, :, 8:]).any()


def test_attention_paged_ops_bit_identical_to_contiguous():
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(1), 3)
    k_pages = _random_pages(k0)
    v_pages = _random_pages(k1)
    table = jnp.array([[1, 3, 0], [2, 4, 5]], jnp.int32)
    buf = 10
    k = gather_kv_pages(k_pages, table, buf)
    v = gather_kv_pages(v_pages, table, buf)
    kv_len = jnp.array([7, 10], jnp.int32)

    q1 = jax.random.normal(k2, (2, 4, 8), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(decode_attention_paged_op(
            q1, k_pages, v_pages, table, kv_len, buf_len=buf,
            use_kernel=False)),
        np.asarray(decode_attention_op(q1, k, v, kv_len,
                                       use_kernel=False)))

    qs = jax.random.normal(k2, (2, 4, 3, 8), jnp.float32)
    qo = jnp.array([4, 7], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(flash_attention_paged_op(
            qs, k_pages, v_pages, table, qo, kv_len, buf_len=buf,
            use_kernel=False)),
        np.asarray(flash_attention_op(qs, k, v, qo, kv_len,
                                      use_kernel=False)))


# ---- model-op bit-identity: paged vs contiguous -----------------------

@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def _prefilled_pair(params, pos=5):
    """Contiguous and paged pools holding identical prefilled state in
    slot 0; slot 1 stays dead (unmapped / zero rows)."""
    cpool = CachePool({"m": CFG}, num_slots=2, rows_per_slot=2, buf_len=16)
    ppool = make_paged(slots=2, rows=2, buf=16, page=4)
    sc, sp = cpool.alloc(), ppool.alloc()
    assert sc == sp == 0
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, pos), 0, 32)
    cache = init_cache(CFG, 2, 16)
    _, cache = prefill(params, CFG, {"tokens": toks}, cache)
    cpool.write_prefill("m", sc, cache, pos=pos)
    ppool.write_prefill("m", sp, cache, pos=pos)
    cpool.set_pos(sc, pos)
    ppool.set_pos(sp, pos)
    return cpool, ppool, 0


def test_prefill_scatter_bit_identical(params):
    cpool, ppool, slot = _prefilled_pair(params)
    rows = cpool.rows_of(slot)
    got = ppool.materialize("m")
    for leaf in ("k", "v"):
        np.testing.assert_array_equal(
            np.asarray(got[leaf])[:, rows],
            np.asarray(cpool.caches["m"][leaf])[:, rows])


def test_decode_and_verify_steps_bit_identical(params):
    cpool, ppool, slot = _prefilled_pair(params)
    rows = cpool.rows_of(slot)
    pos = jnp.asarray(cpool.row_positions())
    tok1 = jax.random.randint(jax.random.PRNGKey(2), (4, 1), 0, 32)

    ppool.reserve(slot, int(cpool.pos[slot]) + 1)
    lc, nc = decode_step_slots(params, CFG, tok1, cpool.caches["m"], pos)
    lp, np_ = decode_step_slots_paged(params, CFG, tok1, ppool.pages["m"],
                                      ppool.pt_device(), pos, buf_len=16)
    np.testing.assert_array_equal(np.asarray(lc)[rows], np.asarray(lp)[rows])
    cpool.update("m", nc)
    ppool.update("m", np_)

    pos = pos + 1
    cpool.set_pos(slot, int(cpool.pos[slot]) + 1)
    ppool.set_pos(slot, int(ppool.pos[slot]) + 1)
    tokm = jax.random.randint(jax.random.PRNGKey(3), (4, 3), 0, 32)
    ppool.reserve(slot, int(cpool.pos[slot]) + 3)
    lc, nc = verify_step_slots(params, CFG, tokm, cpool.caches["m"], pos)
    lp, np_ = verify_step_slots_paged(params, CFG, tokm, ppool.pages["m"],
                                      ppool.pt_device(), pos, buf_len=16)
    np.testing.assert_array_equal(np.asarray(lc)[rows], np.asarray(lp)[rows])
    cpool.update("m", nc)
    ppool.update("m", np_)

    # rollback: replicate row content through winner lanes
    row_src = np.array([1, 1, 2, 3], np.int32)
    cpool.rollback_rows(row_src)
    ppool.rollback_rows(row_src)
    got = ppool.materialize("m")
    for leaf in ("k", "v"):
        np.testing.assert_array_equal(
            np.asarray(got[leaf])[:, rows],
            np.asarray(cpool.caches["m"][leaf])[:, rows])


def test_dead_rows_gather_zeros(params):
    _, ppool, _ = _prefilled_pair(params)
    dead = ppool.rows_of(1)
    got = ppool.materialize("m")
    assert not np.asarray(got["k"])[:, dead].any()
    assert not np.asarray(got["v"])[:, dead].any()
