"""Fused single-dispatch rounds (DESIGN.md §8): the kv_fused path must
be BIT-identical to the host-driven kv path — and, through it, to the
sequential reference scheduler — across all six verification strategies
and both device verifier backends, while spending zero draft syncs and
exactly one host sync per round."""

import jax
import numpy as np
import pytest

from repro.models import ModelConfig, init_params
from repro.specdec import (
    STRATEGIES,
    CachedSpecDecEngine,
    SpecDecConfig,
    SpecDecEngine,
    SpecDecServer,
)

TCFG = ModelConfig(name="t", family="dense", num_layers=3, d_model=64,
                   num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                   vocab_size=64, dtype="float32")
DCFG = TCFG.replace(name="d", num_layers=1)


@pytest.fixture(scope="module")
def pair():
    return (init_params(jax.random.PRNGKey(0), TCFG),
            init_params(jax.random.PRNGKey(1), DCFG))


def _generate_both(pair, strategy, backend, runs=2, max_new=14):
    """(kv output, fused output) per run, identical shared randomness."""
    tp, dp = pair
    k = 1 if strategy in ("single", "daliri") else 4
    sd = SpecDecConfig(num_drafts=k, draft_len=3, strategy=strategy,
                       max_new_tokens=max_new, top_k=0,
                       verifier_backend=backend)
    prompt = np.array([1, 2, 3, 4], np.int32)
    outs = []
    for i in range(runs):
        key = jax.random.PRNGKey(50 + i)
        kv = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd)
        fz = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd)
        outs.append((kv.generate(key, prompt).output,
                     fz.generate(key, prompt, fused=True).output))
    return outs


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_fused_round_bit_identical_to_kv(pair, strategy):
    """The hard contract: fusing the round into one dispatch changes
    dispatch count and sync count, never tokens — exact equality, every
    strategy."""
    for kv_out, fz_out in _generate_both(pair, strategy, "xla"):
        np.testing.assert_array_equal(kv_out, fz_out)


@pytest.mark.slow
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_fused_round_bit_identical_to_kv_pallas(pair, strategy):
    """Nightly sweep: same exactness with the batched gls_race row
    kernel standing in for the xla race reduction."""
    for kv_out, fz_out in _generate_both(pair, strategy, "pallas"):
        np.testing.assert_array_equal(kv_out, fz_out)


def test_fused_scheduler_bit_identical_to_sequential_reference(pair):
    """kv_fused through the scheduler == the sequential re-prefill
    reference trace (the DESIGN.md §1 layering contract, extended)."""
    tp, dp = pair
    sd = SpecDecConfig(num_drafts=2, draft_len=2, strategy="gls", top_k=0)
    outs = {}
    for mode in ("reprefill", "kv_fused"):
        if mode == "reprefill":
            eng = SpecDecEngine((tp, TCFG), [(dp, DCFG)], sd)
        else:
            eng = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd,
                                      pool_slots=2)
        server = SpecDecServer(eng, max_batch=2, cache_mode=mode)
        for _ in range(5):
            server.submit(np.array([1, 2, 3], np.int32), max_new=6)
        done = server.run(jax.random.PRNGKey(7))
        outs[mode] = {r.uid: list(r.output) for r in done}
    assert outs["kv_fused"] == outs["reprefill"]


def test_fused_sync_accounting(pair):
    """DESIGN.md §7.3 (revised): a fused round spends ZERO draft syncs
    (tokens never leave the device mid-round) and exactly ONE host sync
    (the packed result fetch) — so over a server trace,
    draft_syncs == 0 and host_syncs == rounds."""
    tp, dp = pair
    sd = SpecDecConfig(num_drafts=4, draft_len=3, strategy="gls", top_k=0)
    eng = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd, pool_slots=2)
    server = SpecDecServer(eng, max_batch=2, cache_mode="kv_fused")
    for _ in range(3):
        server.submit(np.array([1, 2, 3], np.int32), max_new=8)
    server.run(jax.random.PRNGKey(3))
    m = server.metrics
    assert m.rounds > 0
    assert m.draft_syncs == 0
    assert m.host_syncs == m.rounds
    # ONE stacked verify per round on the target side too.
    assert m.target_forwards == m.rounds
    assert eng.num_draft_syncs == 0


def test_fused_generate_sync_accounting(pair):
    """Single-request accounting: host_syncs == blocks (R=1 rounds)."""
    tp, dp = pair
    sd = SpecDecConfig(num_drafts=4, draft_len=3, strategy="gls",
                       max_new_tokens=16, top_k=0)
    eng = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd)
    o = eng.generate(jax.random.PRNGKey(3), np.array([1, 2, 3], np.int32),
                     fused=True)
    assert o.host_syncs == o.blocks
    assert eng.num_draft_syncs == 0


def test_fused_rejects_legacy_backend(pair):
    """The legacy verifier is a host loop — it cannot run inside the
    fused program and must fail loudly, not silently fall back."""
    tp, dp = pair
    sd = SpecDecConfig(num_drafts=2, draft_len=2, strategy="gls", top_k=0,
                       verifier_backend="legacy")
    eng = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd)
    with pytest.raises(ValueError, match="legacy"):
        eng.generate(jax.random.PRNGKey(0), np.array([1, 2, 3], np.int32),
                     fused=True)


def test_fused_multi_request_matches_solo(pair):
    """Slot isolation survives fusion: two co-resident fused requests
    emit exactly what each emits alone in a one-slot pool."""
    tp, dp = pair
    sd = SpecDecConfig(num_drafts=2, draft_len=2, strategy="gls", top_k=0)
    prompts = {7: np.array([1, 2, 3], np.int32),
               9: np.array([4, 5, 6, 7], np.int32)}
    max_new = 8
    buf = max(len(p) for p in prompts.values()) + max_new + 4

    def drive(engine, uids):
        out = {u: [] for u in uids}
        prefix = {u: list(prompts[u]) for u in uids}
        blocks = {u: 0 for u in uids}
        while any(len(out[u]) < max_new for u in uids):
            live = [u for u in uids if len(out[u]) < max_new]
            subs = [jax.random.fold_in(jax.random.PRNGKey(11), u * 100
                                       + blocks[u]) for u in live]
            res = engine.gen_blocks(
                subs, [np.asarray(prefix[u], np.int32) for u in live],
                buf, uids=live, fused=True)
            for u, o in zip(live, res):
                out[u].extend(o.new_tokens)
                prefix[u].extend(o.new_tokens)
                blocks[u] += 1
                if len(out[u]) >= max_new:
                    engine.release(u)
        return {u: out[u][:max_new] for u in uids}

    multi = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd, pool_slots=2)
    both = drive(multi, [7, 9])
    assert multi.pool.num_free == 2
    for u in (7, 9):
        solo = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sd,
                                   pool_slots=1)
        assert drive(solo, [u]) == {u: both[u]}, f"uid {u} diverged"
