"""Slot-based KV-cache arena: slot lifecycle, buffer growth, prefill
scatter, and rollback-by-row-replication (DESIGN.md §7)."""

import jax
import numpy as np
import pytest

from repro.models import CachePool, ModelConfig, init_cache, init_params, prefill

CFG = ModelConfig(name="p", family="dense", num_layers=2, d_model=32,
                  num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                  vocab_size=32, dtype="float32")


def make_pool(slots=3, rows=2, buf=16):
    return CachePool({"m": CFG}, num_slots=slots, rows_per_slot=rows,
                     buf_len=buf)


def test_alloc_is_lowest_free_slot_first():
    pool = make_pool()
    assert [pool.alloc(), pool.alloc(), pool.alloc()] == [0, 1, 2]
    with pytest.raises(RuntimeError):
        pool.alloc()
    pool.release(1)
    pool.release(0)
    assert pool.alloc() == 0          # lowest free wins, not LIFO
    assert pool.alloc() == 1
    assert pool.num_free == 0


def test_release_resets_position():
    pool = make_pool()
    slot = pool.alloc()
    pool.pos[slot] = 7
    pool.release(slot)
    assert pool.pos[slot] == 0
    with pytest.raises(AssertionError):
        pool.release(slot)            # double free


def test_row_positions_and_free_default():
    pool = make_pool(slots=2, rows=3)
    s = pool.alloc()
    pool.pos[s] = 5
    got = pool.row_positions()
    assert got.tolist() == [5, 5, 5, 0, 0, 0]


def test_write_prefill_and_rollback_replication():
    pool = make_pool(slots=2, rows=2, buf=16)
    params = init_params(jax.random.PRNGKey(0), CFG)
    slot = pool.alloc()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0, 32)
    cache = init_cache(CFG, 2, pool.buf_len)
    _, cache = prefill(params, CFG, {"tokens": toks}, cache)
    pool.write_prefill("m", slot, cache, pos=5)
    assert pool.pos[slot] == 5
    arena = pool.caches["m"]
    np.testing.assert_array_equal(np.asarray(arena["k"][:, 0:2]),
                                  np.asarray(cache["k"]))
    # Replicate row 1 of slot 0 across the slot; slot 1 untouched.
    before_other = np.asarray(arena["k"][:, 2:4])
    pool.rollback_rows(np.array([1, 1, 2, 3]))
    arena = pool.caches["m"]
    np.testing.assert_array_equal(np.asarray(arena["k"][:, 0]),
                                  np.asarray(cache["k"][:, 1]))
    np.testing.assert_array_equal(np.asarray(arena["k"][:, 1]),
                                  np.asarray(cache["k"][:, 1]))
    np.testing.assert_array_equal(np.asarray(arena["k"][:, 2:4]),
                                  before_other)


def test_ensure_buf_grows_and_preserves_content():
    pool = make_pool(slots=1, rows=2, buf=8)
    params = init_params(jax.random.PRNGKey(0), CFG)
    slot = pool.alloc()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, 32)
    cache = init_cache(CFG, 2, pool.buf_len)
    _, cache = prefill(params, CFG, {"tokens": toks}, cache)
    pool.write_prefill("m", slot, cache, pos=6)
    old_k = np.asarray(pool.caches["m"]["k"])
    pool.ensure_buf(20)
    assert pool.buf_len == 20
    new_k = np.asarray(pool.caches["m"]["k"])
    assert new_k.shape[3] == 20
    np.testing.assert_array_equal(new_k[:, :, :, :8], old_k)
    assert not new_k[:, :, :, 8:].any()
    pool.ensure_buf(10)               # never shrinks
    assert pool.buf_len == 20


def test_prefill_buffer_mismatch_rejected():
    pool = make_pool(slots=1, rows=2, buf=16)
    slot = pool.alloc()
    small = init_cache(CFG, 2, 8)
    with pytest.raises(AssertionError):
        pool.write_prefill("m", slot, small, pos=4)


def test_ring_caches_rejected():
    swa = CFG.replace(name="swa", sliding_window=8)
    with pytest.raises(AssertionError):
        CachePool({"m": swa}, num_slots=1, rows_per_slot=1, buf_len=16)
