"""Substrate tests: optimizer, schedules, checkpointing, data pipeline,
WZ codec invariants (hypothesis property tests on system invariants)."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.compression.wz import make_bins, wz_round
from repro.data import lm_dataset, decode as detok, encode
from repro.optim import (
    adam_init,
    adam_update,
    clip_by_global_norm,
    warmup_cosine_schedule,
)
from repro.train import load_checkpoint, save_checkpoint


def test_adam_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adam_init(params)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(300):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, opt, _ = adam_update(params, grads, opt, 0.05)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


@settings(max_examples=20, deadline=None)
@given(st.floats(0.1, 10.0), st.integers(1, 5))
def test_clip_by_global_norm_property(max_norm, seed):
    g = {"a": jax.random.normal(jax.random.PRNGKey(seed), (7,)) * 100}
    clipped, norm = clip_by_global_norm(g, max_norm)
    new_norm = float(jnp.linalg.norm(clipped["a"]))
    assert new_norm <= max_norm * 1.001


def test_warmup_cosine_monotone_warmup():
    lr = warmup_cosine_schedule(1e-3, warmup=10, total_steps=100)
    vals = [float(lr(s)) for s in range(15)]
    assert all(b >= a for a, b in zip(vals[:10], vals[1:11]))
    assert abs(vals[10] - 1e-3) < 1e-4


def test_checkpoint_roundtrip_nested():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2], jnp.int32),
                  "d": [jnp.ones((2,), jnp.bfloat16), "meta"]},
            "step": 7}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.msgpack")
        save_checkpoint(path, tree)
        back = load_checkpoint(path)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(back["b"]["c"]), [1, 2])
    assert back["b"]["d"][1] == "meta"
    assert back["step"] == 7
    assert back["b"]["d"][0].dtype == jnp.bfloat16


def test_tokenizer_roundtrip():
    text = "the decoder verifies a draft exactly ."
    assert detok(encode(text)) == text


def test_lm_dataset_targets_shifted():
    ds = lm_dataset(4, 32, 259, num_sentences=200)
    batch = next(iter(ds))
    assert batch["tokens"].shape == (4, 32)
    # targets are inputs shifted by one within the same stream
    assert batch["tokens"].max() < 259


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 4),
       st.sampled_from([2, 4, 8]))
def test_wz_decoder_respects_bin_property(seed, k, l_max):
    """Invariant: every decoder's selected atom lies in the transmitted
    bin (the 1{l_i = M} mask), whatever the weights."""
    n = 64
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    log_w_enc = jax.random.normal(k1, (n,))
    log_w_dec = jax.random.normal(k2, (k, n))
    bins = make_bins(k3, n, l_max)
    code = wz_round(key, log_w_enc, log_w_dec, bins, k)
    assert bool(jnp.all(bins[code.x] == code.message))
    # Encoder's own atom is trivially in the bin it announced.
    assert int(bins[code.y]) == int(code.message)


def test_wz_k1_shared_equals_gls():
    n, l_max = 128, 4
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    log_w_enc = jax.random.normal(k1, (n,))
    log_w_dec = jax.random.normal(k2, (1, n))
    bins = make_bins(k3, n, l_max)
    a = wz_round(key, log_w_enc, log_w_dec, bins, 1)
    b = wz_round(key, log_w_enc, log_w_dec, bins, 1, shared_sheet=True)
    assert int(a.y) == int(b.y) and int(a.x[0]) == int(b.x[0])
