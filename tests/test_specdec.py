"""Speculative decoding correctness:

* Proposition 3 sequence-level correctness — the verifier's per-step output
  marginal equals the target distribution for EVERY strategy (synthetic
  distributions, many trials).
* Conditional drafter invariance (Definition 1) — GLS verification depends
  on the drafts only through their token values, never their logits.
* Block-efficiency sanity — multi-draft GLS beats single-draft coupling.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, init_params
from repro.specdec import (
    SpecDecConfig,
    SpecDecEngine,
    daliri_verify,
    draft_token_from_uniforms,
    gls_verify,
    gls_verify_strong,
    single_draft_verify,
    specinfer_verify,
    spectr_verify,
)

N, K = 12, 4
TRIALS = 12_000


def _dists(seed):
    kp, kq = jax.random.split(jax.random.PRNGKey(seed))
    p = jax.random.dirichlet(kp, jnp.ones(N))
    q = jax.random.dirichlet(kq, jnp.ones(N))
    return p, q


def _one_step(strategy, key, p, q):
    """Run one verification step; return the emitted token."""
    k_u, k_s = jax.random.split(key)
    log_u = jnp.log(jax.random.uniform(k_u, (K, N), minval=1e-37, maxval=1.0))
    draft_toks = draft_token_from_uniforms(log_u, jnp.broadcast_to(p, (K, N)))
    qk = jnp.broadcast_to(q, (K, N))
    pk = jnp.broadcast_to(p, (K, N))
    active = jnp.ones((K,), bool)
    if strategy == "gls":
        return gls_verify(log_u, draft_toks, qk, active).token
    if strategy == "gls_strong":
        return gls_verify_strong(log_u, draft_toks, qk, active).token
    if strategy == "specinfer":
        return specinfer_verify(k_s, pk, draft_toks, qk, active).token
    if strategy == "spectr":
        return spectr_verify(k_s, pk, draft_toks, qk, active).token
    if strategy == "single":
        return single_draft_verify(k_s, p, draft_toks[0], q).token
    if strategy == "daliri":
        return daliri_verify(log_u[0], draft_toks[0], q).token
    raise ValueError(strategy)


@pytest.mark.parametrize(
    "strategy", ["gls", "gls_strong", "specinfer", "spectr", "single",
                 "daliri"])
def test_output_marginal_is_target(strategy):
    """Whatever the strategy, the emitted token must follow q exactly."""
    p, q = _dists(0)
    keys = jax.random.split(jax.random.PRNGKey(1), TRIALS)
    toks = jax.vmap(lambda kk: _one_step(strategy, kk, p, q))(keys)
    hist = np.bincount(np.asarray(toks), minlength=N) / TRIALS
    tv = 0.5 * np.abs(hist - np.asarray(q)).sum()
    # TV of an N-bin empirical estimate at this sample size.
    assert tv < 0.025, (strategy, tv)


def test_gls_acceptance_beats_single_draft():
    p, q = _dists(2)
    keys = jax.random.split(jax.random.PRNGKey(3), TRIALS)

    def accept_of(strategy):
        def one(kk):
            k_u, k_s = jax.random.split(kk)
            log_u = jnp.log(jax.random.uniform(k_u, (K, N), minval=1e-37,
                                               maxval=1.0))
            d = draft_token_from_uniforms(log_u, jnp.broadcast_to(p, (K, N)))
            if strategy == "gls":
                return gls_verify(log_u, d, jnp.broadcast_to(q, (K, N)),
                                  jnp.ones((K,), bool)).accepted
            return daliri_verify(log_u[0], d[0], q).accepted
        return float(jnp.mean(jax.vmap(one)(keys)))

    assert accept_of("gls") > accept_of("daliri") + 0.05


@pytest.mark.slow
def test_verify_is_drafter_invariant_by_construction():
    """Definition 1: gls_verify consumes only token VALUES — feeding the
    same tokens with wildly different 'drafter' provenance must give a
    bit-identical result.  (SpecInfer, by contrast, changes output when
    draft probs change.)"""
    p1, q = _dists(4)
    p2 = jnp.roll(p1, 3)  # a very different drafter
    key = jax.random.PRNGKey(5)
    log_u = jnp.log(jax.random.uniform(key, (K, N), minval=1e-37, maxval=1.0))
    d = draft_token_from_uniforms(log_u, jnp.broadcast_to(p1, (K, N)))
    active = jnp.ones((K,), bool)
    qk = jnp.broadcast_to(q, (K, N))
    r1 = gls_verify(log_u, d, qk, active)
    r2 = gls_verify(log_u, d, qk, active)  # same tokens, any drafter
    assert int(r1.token) == int(r2.token)
    assert bool(r1.accepted) == bool(r2.accepted)

    # SpecInfer is NOT invariant: different draft probs, same tokens, same
    # randomness -> output can change (this is the paper's point).  Use a
    # crafted case where q(x)/p(x) straddles 1 across the two drafters.
    n4 = 4
    q4 = jnp.full((n4,), 0.25)
    pa = jnp.array([0.85, 0.05, 0.05, 0.05])   # q/pa(0) = 0.29 < 1
    pb = jnp.array([0.10, 0.30, 0.30, 0.30])   # q/pb(0) = 2.5  > 1
    d4 = jnp.zeros((K,), jnp.int32)            # all drafts propose token 0
    act = jnp.ones((K,), bool)
    q4k = jnp.broadcast_to(q4, (K, n4))
    diffs = 0
    for i in range(50):
        kk = jax.random.fold_in(jax.random.PRNGKey(6), i)
        s1 = specinfer_verify(kk, jnp.broadcast_to(pa, (K, n4)), d4, q4k, act)
        s2 = specinfer_verify(kk, jnp.broadcast_to(pb, (K, n4)), d4, q4k, act)
        diffs += int(int(s1.token) != int(s2.token))
    assert diffs > 0, "expected SpecInfer outputs to depend on draft logits"


def test_engine_conditional_invariance():
    """Engine-level Def. 1: two different drafters; whenever the sampled
    draft TOKENS coincide for a block, the GLS output for that block must
    coincide too."""
    tcfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=48,
                       num_heads=4, num_kv_heads=2, head_dim=12, d_ff=96,
                       vocab_size=32, dtype="float32")
    dcfg1 = tcfg.replace(name="d1", num_layers=1)
    tp = init_params(jax.random.PRNGKey(0), tcfg)
    dp1 = init_params(jax.random.PRNGKey(1), dcfg1)
    # Drafter 2: a small perturbation — usually same race winners, always
    # different logits.
    dp2 = jax.tree.map(lambda a: a * (1.0 + 1e-4), dp1)

    sd = SpecDecConfig(num_drafts=2, draft_len=3, strategy="gls",
                       max_new_tokens=6, top_k=0)
    e1 = SpecDecEngine((tp, tcfg), [(dp1, dcfg1)], sd)
    e2 = SpecDecEngine((tp, tcfg), [(dp2, dcfg1)], sd)
    prompt = np.array([1, 2, 3], np.int32)

    matched = 0
    for i in range(10):
        key = jax.random.PRNGKey(100 + i)
        o1 = e1.generate(key, prompt, max_new=4)
        o2 = e2.generate(key, prompt, max_new=4)
        # Conditional invariance: same randomness and (almost surely) same
        # drafts => same outputs.
        if np.array_equal(o1.output, o2.output):
            matched += 1
    assert matched >= 8, f"only {matched}/10 blocks drafter-invariant"


def test_engine_multi_draft_improves_be():
    tcfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=48,
                       num_heads=4, num_kv_heads=2, head_dim=12, d_ff=96,
                       vocab_size=32, dtype="float32")
    dcfg = tcfg.replace(name="d", num_layers=1)
    tp = init_params(jax.random.PRNGKey(0), tcfg)
    dp = init_params(jax.random.PRNGKey(1), dcfg)
    prompt = np.array([1, 2, 3], np.int32)

    def be(strategy, k):
        eng = SpecDecEngine((tp, tcfg), [(dp, dcfg)],
                            SpecDecConfig(num_drafts=k, draft_len=3,
                                          strategy=strategy,
                                          max_new_tokens=32, top_k=0))
        outs = [eng.generate(jax.random.PRNGKey(10 + i), prompt)
                for i in range(4)]
        return float(np.mean([o.block_efficiency for o in outs]))

    assert be("gls", 8) > be("daliri", 1) - 0.05
