"""Serving scheduler: queueing, admission, completion, metrics, RNG
stream derivation, cache_mode="kv" equivalence, and the paged-arena
continuous-batching v2 policy (eviction, preemption, streaming —
DESIGN.md §12)."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.models import ModelConfig, init_params
from repro.specdec import (
    STRATEGIES,
    CachedSpecDecEngine,
    SpecDecConfig,
    SpecDecEngine,
)
from repro.specdec.scheduler import SpecDecServer

TCFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=48,
                   num_heads=4, num_kv_heads=2, head_dim=12, d_ff=96,
                   vocab_size=32, dtype="float32")
DCFG = TCFG.replace(name="d", num_layers=1)
SD = SpecDecConfig(num_drafts=2, draft_len=2, strategy="gls", top_k=0)


@pytest.fixture(scope="module")
def pair():
    return (init_params(jax.random.PRNGKey(0), TCFG),
            init_params(jax.random.PRNGKey(1), DCFG))


def make_server(pair, *, cache_mode="reprefill", batched=False,
                max_batch=2):
    tp, dp = pair
    if cache_mode == "kv":
        eng = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), SD,
                                  pool_slots=max_batch)
    else:
        eng = SpecDecEngine((tp, TCFG), [(dp, DCFG)], SD)
    return SpecDecServer(eng, max_batch=max_batch, batched=batched,
                         cache_mode=cache_mode)


def run_trace(server, n=5, max_new=6):
    uids = [server.submit(np.array([1, 2, 3], np.int32), max_new=max_new)
            for _ in range(n)]
    done = server.run(jax.random.PRNGKey(7))
    return uids, done


def test_server_drains_queue_with_metrics(pair):
    server = make_server(pair)
    uids, done = run_trace(server)
    assert len(done) == 5
    assert sorted(r.uid for r in done) == sorted(uids)
    for r in done:
        assert len(r.output) == 6
        assert r.t_first is not None and r.t_done is not None
    m = server.metrics
    assert m.completed == 5
    assert m.total_tokens == 30
    assert m.tokens_per_s > 0
    assert 1.0 <= m.mean_block_efficiency <= 3.0


def test_kv_mode_bit_identical_to_sequential_reference(pair):
    """The tentpole contract: serving from persistent KV caches changes
    speed, never tokens (DESIGN.md §1, §7)."""
    outs = {}
    for mode in ("reprefill", "kv"):
        server = make_server(pair, cache_mode=mode)
        _, done = run_trace(server)
        outs[mode] = {r.uid: list(r.output) for r in done}
    assert outs["kv"] == outs["reprefill"]


def test_kv_mode_releases_slots_and_counts_forwards(pair):
    server = make_server(pair, cache_mode="kv")
    _, done = run_trace(server)
    assert len(done) == 5
    eng = server.engine
    assert eng.pool.num_free == eng.pool.num_slots
    # ONE stacked verify per round, vs R re-score forwards sequentially.
    assert server.metrics.target_forwards == server.metrics.rounds
    assert server.metrics.draft_syncs > 0


def test_kv_mode_rejects_reference_engine(pair):
    tp, dp = pair
    eng = SpecDecEngine((tp, TCFG), [(dp, DCFG)], SD)
    with pytest.raises(TypeError, match="CachedSpecDecEngine"):
        SpecDecServer(eng, cache_mode="kv")
    with pytest.raises(ValueError, match="unknown cache_mode"):
        SpecDecServer(eng, cache_mode="mystery")
    cached = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), SD, pool_slots=1)
    with pytest.raises(ValueError, match="slots"):
        SpecDecServer(cached, max_batch=4, cache_mode="kv")


def test_rng_streams_no_flat_encoding_collision():
    """Regression: the flat ``fold_in(key, uid * 1000 + blocks)`` stream
    collides across requests once a request reaches 1000 blocks —
    (uid=1, blocks=1000) and (uid=2, blocks=0) both folded 2000, giving
    two requests identical randomness.  The nested derivation keeps the
    streams distinct."""
    key = jax.random.PRNGKey(7)
    flat = lambda uid, blocks: jax.random.fold_in(key, uid * 1000 + blocks)
    nested = lambda uid, blocks: jax.random.fold_in(
        jax.random.fold_in(key, uid), blocks)
    collide_a, collide_b = (1, 1000), (2, 0)
    assert np.array_equal(  # the bug this guards against
        jax.random.key_data(flat(*collide_a)),
        jax.random.key_data(flat(*collide_b)))
    assert not np.array_equal(
        jax.random.key_data(nested(*collide_a)),
        jax.random.key_data(nested(*collide_b)))


def test_scheduler_uses_nested_rng_streams(pair):
    """The scheduler's per-request subkeys must follow the nested
    contract: same trace, uids remapped by +1, all streams distinct."""
    server = make_server(pair)
    seen = []
    orig = server.engine.gen_block

    def spy(sub, prefix, buf_len):
        seen.append(np.asarray(jax.random.key_data(sub)).tolist())
        return orig(sub, prefix, buf_len)

    server.engine.gen_block = spy
    run_trace(server, n=3, max_new=4)
    assert len(seen) == len({tuple(s) for s in seen}), \
        "duplicate RNG stream across request blocks"


def test_wall_s_accumulates_under_direct_step(pair):
    """Regression: only ``run()`` used to set wall_s, so driving
    ``step()`` directly reported tokens/s against the 1e-9 floor."""
    server = make_server(pair)
    server.submit(np.array([1, 2, 3], np.int32), max_new=4)
    rounds = 0
    while (server.queue or server.live) and rounds < 50:
        server.step(jax.random.fold_in(jax.random.PRNGKey(3), rounds))
        rounds += 1
    m = server.metrics
    assert m.total_tokens >= 4
    assert m.wall_s > 0
    assert m.tokens_per_s < 1e7, "tokens_per_s divided by the 1e-9 floor"


# ---- paged KV arena + continuous batching v2 (DESIGN.md §12) ---------

PROMPTS = [np.arange(1, 1 + n, dtype=np.int32) % 31 + 1
           for n in (3, 5, 4, 6)]
MAX_NEW = 6


def _min_buf(sd, prompts=PROMPTS, max_new=MAX_NEW):
    """Pin the buffer to the trace's maximum requirement so outputs are
    bit-comparable across policies (buffer LENGTH changes compiled
    reduction shapes; v2's live set depends on arrival order)."""
    return max(len(p) for p in prompts) + max_new + sd.draft_len + 2


def _oracle(pair, sd, prompts=PROMPTS, priorities=None):
    """Sequential reprefill FIFO reference outputs, keyed by uid."""
    tp, dp = pair
    srv = SpecDecServer(SpecDecEngine((tp, TCFG), [(dp, DCFG)], sd),
                        max_batch=2, cache_mode="reprefill",
                        min_buf_len=_min_buf(sd, prompts))
    for i, p in enumerate(prompts):
        srv.submit(p, max_new=MAX_NEW,
                   priority=0 if priorities is None else priorities[i])
    done = srv.run(jax.random.PRNGKey(7))
    return {r.uid: list(r.output) for r in done}


def _paged_engine(pair, sd, *, pool_slots=2, pool_pages=None):
    tp, dp = pair
    sdp = dataclasses.replace(sd, paged=True, page_size=8)
    return CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sdp,
                               pool_slots=pool_slots,
                               pool_pages=pool_pages)


def test_v2_policy_validation(pair):
    tp, dp = pair
    ref = SpecDecEngine((tp, TCFG), [(dp, DCFG)], SD)
    cached = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), SD, pool_slots=2)
    with pytest.raises(ValueError, match="unknown policy"):
        SpecDecServer(cached, cache_mode="kv", policy="mystery")
    with pytest.raises(ValueError, match="v2"):
        SpecDecServer(ref, cache_mode="reprefill", policy="v2")
    with pytest.raises(ValueError, match="preempt_tokens"):
        SpecDecServer(ref, cache_mode="reprefill", preempt_tokens=4)
    with pytest.raises(ValueError, match="preempt_tokens"):
        SpecDecServer(cached, cache_mode="kv", policy="v2",
                      preempt_tokens=0)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_oversubscribed_paged_v2_bit_identical_all_strategies(
        pair, strategy):
    """The PR's acceptance gate: an oversubscribed trace on the paged
    arena under the v2 policy (fixed page budget, preemption rotating
    slots) emits tokens bit-identical to the sequential reprefill
    reference, for every strategy, with the fused round's zero
    draft-sync contract intact."""
    sd = dataclasses.replace(SD, strategy=strategy)
    want = _oracle(pair, sd)
    eng = _paged_engine(pair, sd, pool_pages=24)
    srv = SpecDecServer(eng, max_batch=2, cache_mode="kv_fused",
                        policy="v2", preempt_tokens=3,
                        min_buf_len=_min_buf(sd))
    for p in PROMPTS:
        srv.submit(p, max_new=MAX_NEW)
    done = srv.run(jax.random.PRNGKey(7))
    assert {r.uid: list(r.output) for r in done} == want
    assert srv.metrics.preemptions > 0, "trace did not rotate slots"
    assert srv.metrics.draft_syncs == 0
    assert eng.pool.num_free == eng.pool.num_slots
    st = eng.page_state()
    assert st["free"] == st["total"]


def test_mid_generation_eviction_readmission_bit_identical(pair):
    """A late high-priority arrival evicts a mid-generation request
    (session released, pages freed); the victim re-admits via a
    re-prefill of prompt+output and finishes with the exact tokens it
    would have emitted uninterrupted — eviction time stays visible in
    the victim's accounting instead of vanishing."""
    prios = [0, 0, 5, 0]
    want = _oracle(pair, SD, priorities=prios)
    for cache_mode in ("kv", "kv_fused"):
        eng = _paged_engine(pair, SD, pool_pages=16)
        srv = SpecDecServer(eng, max_batch=2, cache_mode=cache_mode,
                            policy="v2", min_buf_len=_min_buf(SD))
        key = jax.random.PRNGKey(7)
        srv.submit(PROMPTS[0], max_new=MAX_NEW)
        srv.submit(PROMPTS[1], max_new=MAX_NEW)
        srv.step(key)
        srv.step(key)                          # both mid-generation
        srv.submit(PROMPTS[2], max_new=MAX_NEW, priority=5)
        srv.submit(PROMPTS[3], max_new=MAX_NEW)
        done = list(srv.run(key))
        assert {r.uid: list(r.output) for r in done} == want
        assert srv.metrics.evictions >= 1
        victims = [r for r in done if r.evictions]
        assert victims, "no request was evicted"
        for r in victims:
            assert r.evicted_s > 0
        for r in done:
            assert len(r.token_times) == len(r.output)
            assert r.token_times == sorted(r.token_times)
            assert r.wall_s >= r.evicted_s
        assert eng.pool.num_free == eng.pool.num_slots


def test_preemption_rotates_and_reuses_slots(pair):
    """Equal-priority fairness: with preempt_tokens=2 every live
    request yields its slot (and pages) after two tokens while others
    wait; rotation must not change a single token."""
    want = _oracle(pair, SD)
    eng = _paged_engine(pair, SD)
    srv = SpecDecServer(eng, max_batch=2, cache_mode="kv",
                        policy="v2", preempt_tokens=2,
                        min_buf_len=_min_buf(SD))
    for p in PROMPTS:
        srv.submit(p, max_new=MAX_NEW)
    done = srv.run(jax.random.PRNGKey(7))
    assert {r.uid: list(r.output) for r in done} == want
    assert srv.metrics.preemptions >= len(PROMPTS), \
        "every request should be preempted at least once"
    # Rotation means slots were released and re-allocated repeatedly.
    assert max(r.evictions for r in done) >= 1
    assert eng.pool.num_free == eng.pool.num_slots


def test_on_token_streaming_matches_final_output(pair):
    """``on_token`` fires once per emitted token, in emission order,
    at round-commit time — the streamed sequence IS the final output."""
    streamed = {}
    eng = _paged_engine(pair, SD)
    srv = SpecDecServer(eng, max_batch=2, cache_mode="kv_fused",
                        policy="v2", preempt_tokens=3,
                        min_buf_len=_min_buf(SD))
    for p in PROMPTS:
        srv.submit(p, max_new=MAX_NEW,
                   on_token=lambda uid, tok: streamed.setdefault(
                       uid, []).append(tok))
    done = srv.run(jax.random.PRNGKey(7))
    assert streamed == {r.uid: list(r.output) for r in done}


def test_bucket_straddling_prompts_paged_bit_identical(pair):
    """Prompts whose lengths land in different admission buckets join
    one wave; the paged prefill scatter must stay bit-identical across
    the bucket split."""
    prompts = [np.arange(1, 1 + n, dtype=np.int32) % 31 + 1
               for n in (3, 9, 4, 12)]
    want = _oracle(pair, SD, prompts=prompts)
    eng = _paged_engine(pair, SD, pool_slots=4)
    srv = SpecDecServer(eng, max_batch=4, cache_mode="kv_fused",
                        min_buf_len=_min_buf(SD, prompts))
    for p in prompts:
        srv.submit(p, max_new=MAX_NEW)
    done = srv.run(jax.random.PRNGKey(7))
    assert {r.uid: list(r.output) for r in done} == want


def test_fifo_fixed_page_budget_exhaustion_is_loud(pair):
    """FIFO does no page accounting: oversubscribing a fixed budget
    must fail loudly mid-admission, not corrupt state — managing the
    budget is exactly what policy='v2' adds."""
    from repro.models import PagePoolExhausted
    eng = _paged_engine(pair, SD, pool_pages=4)
    srv = SpecDecServer(eng, max_batch=2, cache_mode="kv",
                        min_buf_len=_min_buf(SD))
    for p in PROMPTS:
        srv.submit(p, max_new=MAX_NEW)
    with pytest.raises(PagePoolExhausted):
        srv.run(jax.random.PRNGKey(7))


@pytest.mark.slow
def test_paged_v2_bit_identical_under_pallas_kernels(pair):
    """xla/pallas leg of the paged gate: with the decode + prefill
    Pallas kernels on (interpret mode — the kernel body), paged serving
    matches CONTIGUOUS serving under the same kernels bit-for-bit (the
    kernels run on the gathered view, so the indirection cancels)."""
    sd = dataclasses.replace(SD, decode_kernel=True, prefill_kernel=True,
                             pallas_interpret=True)
    outs = {}
    for paged in (False, True):
        tp, dp = pair
        sdx = dataclasses.replace(sd, paged=paged, page_size=8)
        eng = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), sdx,
                                  pool_slots=2)
        srv = SpecDecServer(eng, max_batch=2, cache_mode="kv_fused",
                            policy="v2", preempt_tokens=3,
                            min_buf_len=_min_buf(sd))
        for p in PROMPTS:
            srv.submit(p, max_new=MAX_NEW)
        done = srv.run(jax.random.PRNGKey(7))
        outs[paged] = {r.uid: list(r.output) for r in done}
    assert outs[True] == outs[False]
