"""Serving scheduler: queueing, admission, completion, metrics, RNG
stream derivation, and cache_mode="kv" equivalence."""

import jax
import numpy as np
import pytest

from repro.models import ModelConfig, init_params
from repro.specdec import CachedSpecDecEngine, SpecDecConfig, SpecDecEngine
from repro.specdec.scheduler import SpecDecServer

TCFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=48,
                   num_heads=4, num_kv_heads=2, head_dim=12, d_ff=96,
                   vocab_size=32, dtype="float32")
DCFG = TCFG.replace(name="d", num_layers=1)
SD = SpecDecConfig(num_drafts=2, draft_len=2, strategy="gls", top_k=0)


@pytest.fixture(scope="module")
def pair():
    return (init_params(jax.random.PRNGKey(0), TCFG),
            init_params(jax.random.PRNGKey(1), DCFG))


def make_server(pair, *, cache_mode="reprefill", batched=False,
                max_batch=2):
    tp, dp = pair
    if cache_mode == "kv":
        eng = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), SD,
                                  pool_slots=max_batch)
    else:
        eng = SpecDecEngine((tp, TCFG), [(dp, DCFG)], SD)
    return SpecDecServer(eng, max_batch=max_batch, batched=batched,
                         cache_mode=cache_mode)


def run_trace(server, n=5, max_new=6):
    uids = [server.submit(np.array([1, 2, 3], np.int32), max_new=max_new)
            for _ in range(n)]
    done = server.run(jax.random.PRNGKey(7))
    return uids, done


def test_server_drains_queue_with_metrics(pair):
    server = make_server(pair)
    uids, done = run_trace(server)
    assert len(done) == 5
    assert sorted(r.uid for r in done) == sorted(uids)
    for r in done:
        assert len(r.output) == 6
        assert r.t_first is not None and r.t_done is not None
    m = server.metrics
    assert m.completed == 5
    assert m.total_tokens == 30
    assert m.tokens_per_s > 0
    assert 1.0 <= m.mean_block_efficiency <= 3.0


def test_kv_mode_bit_identical_to_sequential_reference(pair):
    """The tentpole contract: serving from persistent KV caches changes
    speed, never tokens (DESIGN.md §1, §7)."""
    outs = {}
    for mode in ("reprefill", "kv"):
        server = make_server(pair, cache_mode=mode)
        _, done = run_trace(server)
        outs[mode] = {r.uid: list(r.output) for r in done}
    assert outs["kv"] == outs["reprefill"]


def test_kv_mode_releases_slots_and_counts_forwards(pair):
    server = make_server(pair, cache_mode="kv")
    _, done = run_trace(server)
    assert len(done) == 5
    eng = server.engine
    assert eng.pool.num_free == eng.pool.num_slots
    # ONE stacked verify per round, vs R re-score forwards sequentially.
    assert server.metrics.target_forwards == server.metrics.rounds
    assert server.metrics.draft_syncs > 0


def test_kv_mode_rejects_reference_engine(pair):
    tp, dp = pair
    eng = SpecDecEngine((tp, TCFG), [(dp, DCFG)], SD)
    with pytest.raises(TypeError, match="CachedSpecDecEngine"):
        SpecDecServer(eng, cache_mode="kv")
    with pytest.raises(ValueError, match="unknown cache_mode"):
        SpecDecServer(eng, cache_mode="mystery")
    cached = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), SD, pool_slots=1)
    with pytest.raises(ValueError, match="slots"):
        SpecDecServer(cached, max_batch=4, cache_mode="kv")


def test_rng_streams_no_flat_encoding_collision():
    """Regression: the flat ``fold_in(key, uid * 1000 + blocks)`` stream
    collides across requests once a request reaches 1000 blocks —
    (uid=1, blocks=1000) and (uid=2, blocks=0) both folded 2000, giving
    two requests identical randomness.  The nested derivation keeps the
    streams distinct."""
    key = jax.random.PRNGKey(7)
    flat = lambda uid, blocks: jax.random.fold_in(key, uid * 1000 + blocks)
    nested = lambda uid, blocks: jax.random.fold_in(
        jax.random.fold_in(key, uid), blocks)
    collide_a, collide_b = (1, 1000), (2, 0)
    assert np.array_equal(  # the bug this guards against
        jax.random.key_data(flat(*collide_a)),
        jax.random.key_data(flat(*collide_b)))
    assert not np.array_equal(
        jax.random.key_data(nested(*collide_a)),
        jax.random.key_data(nested(*collide_b)))


def test_scheduler_uses_nested_rng_streams(pair):
    """The scheduler's per-request subkeys must follow the nested
    contract: same trace, uids remapped by +1, all streams distinct."""
    server = make_server(pair)
    seen = []
    orig = server.engine.gen_block

    def spy(sub, prefix, buf_len):
        seen.append(np.asarray(jax.random.key_data(sub)).tolist())
        return orig(sub, prefix, buf_len)

    server.engine.gen_block = spy
    run_trace(server, n=3, max_new=4)
    assert len(seen) == len({tuple(s) for s in seen}), \
        "duplicate RNG stream across request blocks"


def test_wall_s_accumulates_under_direct_step(pair):
    """Regression: only ``run()`` used to set wall_s, so driving
    ``step()`` directly reported tokens/s against the 1e-9 floor."""
    server = make_server(pair)
    server.submit(np.array([1, 2, 3], np.int32), max_new=4)
    rounds = 0
    while (server.queue or server.live) and rounds < 50:
        server.step(jax.random.fold_in(jax.random.PRNGKey(3), rounds))
        rounds += 1
    m = server.metrics
    assert m.total_tokens >= 4
    assert m.wall_s > 0
    assert m.tokens_per_s < 1e7, "tokens_per_s divided by the 1e-9 floor"
