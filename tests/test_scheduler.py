"""Serving scheduler: queueing, admission, completion, metrics."""

import jax
import numpy as np

from repro.models import ModelConfig, init_params
from repro.specdec import SpecDecConfig, SpecDecEngine
from repro.specdec.scheduler import SpecDecServer


def test_server_drains_queue_with_metrics():
    tcfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=48,
                       num_heads=4, num_kv_heads=2, head_dim=12, d_ff=96,
                       vocab_size=32, dtype="float32")
    dcfg = tcfg.replace(name="d", num_layers=1)
    tp = init_params(jax.random.PRNGKey(0), tcfg)
    dp = init_params(jax.random.PRNGKey(1), dcfg)
    eng = SpecDecEngine((tp, tcfg), [(dp, dcfg)],
                        SpecDecConfig(num_drafts=2, draft_len=2,
                                      strategy="gls", top_k=0))
    server = SpecDecServer(eng, max_batch=2)
    uids = [server.submit(np.array([1, 2, 3], np.int32), max_new=6)
            for _ in range(5)]
    done = server.run(jax.random.PRNGKey(7))
    assert len(done) == 5
    assert sorted(r.uid for r in done) == sorted(uids)
    for r in done:
        assert len(r.output) == 6
        assert r.t_first is not None and r.t_done is not None
    m = server.metrics
    assert m.completed == 5
    assert m.total_tokens == 30
    assert m.tokens_per_s > 0
    assert 1.0 <= m.mean_block_efficiency <= 3.0
