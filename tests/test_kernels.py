"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Kernel-body tests pass ``interpret=True`` explicitly — the interpreter
executes the same kernel structure (grid, BlockSpecs, accumulator
sweeps) that compiles on TPU/GPU, so these sweeps ARE the compiled-mode
contract runnable on CPU.  Default-mode (``interpret=None``) tests pin
the backend-autodetected fallback to the reference, bit for bit."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.gls_race.kernel import gls_race, gls_row_race
from repro.kernels.gls_race.ref import gls_race_ref, gls_row_race_ref


# ---------------------------------------------------------------------------
# gls_race
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,k,n,tile", [
    (1, 1, 128, 128),
    (2, 4, 500, 128),
    (3, 8, 1024, 256),
    (1, 2, 50_000, 8192),   # large-vocab case (recurrentgemma-scale / 5)
])
def test_gls_race_matches_ref(b, k, n, tile):
    key = jax.random.PRNGKey(n)
    ku, kp, kq = jax.random.split(key, 3)
    u = jax.random.uniform(ku, (b, k, n), minval=1e-30, maxval=1.0)
    log_s = jnp.log(-jnp.log(u))
    log_p = jnp.log(jax.random.dirichlet(kp, jnp.ones(n), (b, k)))
    log_q = jnp.log(jax.random.dirichlet(kq, jnp.ones(n), (b, k)))
    active = jax.random.bernoulli(kq, 0.7, (b, k))
    active = active.at[:, 0].set(True)  # at least one active
    x, y = gls_race(log_s, log_p, log_q, active, tile_n=tile,
                    interpret=True)
    xr, yr = gls_race_ref(log_s, log_p, log_q, active)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(xr))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


@pytest.mark.parametrize("b,k,n", [
    (1, 1, 128),      # minimal
    (5, 8, 128),      # the serving-bench shape: small vocab, B = L+1
    (3, 4, 500),      # unaligned vocab (lane padding path)
    (20, 4, 128),     # fused-round shape: B = S * (L+1), row bucketing
    (2, 2, 50_000),   # large vocab, many tiles
])
def test_gls_row_race_matches_ref(b, k, n):
    """The tuned (row-blocked, vocab-fitted, B-bucketed) row kernel must
    stay BIT-identical to the jnp row statistics — backend
    interchangeability of the fused verifier depends on it."""
    key = jax.random.PRNGKey(b * 1000 + n)
    ku, kq = jax.random.split(key)
    u = jax.random.uniform(ku, (b, k, n), minval=1e-30, maxval=1.0)
    log_s = jnp.log(-jnp.log(u))
    q = jax.random.dirichlet(kq, jnp.ones(n), (b, k))
    q = q.at[..., : n // 4].set(0.0)       # zero-prob symbols never win
    q = q / q.sum(-1, keepdims=True)
    log_q = jnp.where(q > 0, jnp.log(jnp.maximum(q, 1e-37)), -jnp.inf)
    rmin, rarg = gls_row_race(log_s, log_q, interpret=True)
    rmin_r, rarg_r = gls_row_race_ref(log_s, log_q)
    np.testing.assert_array_equal(np.asarray(rmin), np.asarray(rmin_r))
    np.testing.assert_array_equal(np.asarray(rarg), np.asarray(rarg_r))
    assert bool(jnp.all(rarg >= n // 4))


def test_gls_row_race_bucketed_batches_share_a_kernel():
    """Row bucketing pins nearby batch sizes to one padded shape, so the
    per-B recompile the fused round would otherwise trigger (L+1 rows
    for one request, S*(L+1) for a fused arena) never happens."""
    from repro.kernels.gls_race.kernel import _row_race_tiling
    tile5, rb5, pad5 = _row_race_tiling(5, 8, 128, 2048)
    tile7, rb7, pad7 = _row_race_tiling(7, 8, 128, 2048)
    assert tile5 == tile7 == 128          # vocab tile fits the vocab
    assert pad5 == pad7                   # one compiled kernel for both
    assert rb5 == rb7


@pytest.mark.parametrize("b,k,n,l_max", [
    (1, 1, 128, 2),       # minimal
    (3, 4, 500, 4),       # unaligned atom count (lane padding path)
    (2, 2, 4100, 8),      # several vocab tiles + padding
    (9, 3, 2 ** 14, 4),   # the wz-pipeline shape class (row bucketing)
])
def test_gls_binned_race_matches_ref(b, k, n, l_max):
    """The compression kernel must stay BIT-identical to the jnp binned
    statistics — backend interchangeability of the Wyner–Ziv pipeline
    depends on it (DESIGN.md §10.4)."""
    from repro.kernels.gls_race.kernel import gls_binned_race
    from repro.kernels.gls_race.ref import gls_binned_race_ref
    key = jax.random.PRNGKey(b * 1000 + n)
    ks, kq, kb = jax.random.split(key, 3)
    log_s = jnp.log(jnp.maximum(jax.random.exponential(ks, (b, k, n)),
                                1e-37))
    log_q = jax.random.normal(kq, (b, k, n))
    # Dead atoms (-inf weight) must never win; +inf garbage weights are
    # equally dead on both implementations (isfinite masking).
    log_q = jnp.where(jax.random.bernoulli(kq, 0.8, (b, k, n)), log_q,
                      -jnp.inf)
    log_q = jnp.where(jax.random.bernoulli(kb, 0.02, (b, k, n)), jnp.inf,
                      log_q)
    bins = jax.random.randint(kb, (b, n), 0, l_max)
    bmin, barg = gls_binned_race(log_s, log_q, bins, l_max=l_max,
                                 interpret=True)
    bmin_r, barg_r = gls_binned_race_ref(log_s, log_q, bins, l_max=l_max)
    np.testing.assert_array_equal(np.asarray(bmin), np.asarray(bmin_r))
    np.testing.assert_array_equal(np.asarray(barg), np.asarray(barg_r))


def test_gls_binned_race_empty_bin_reports_inf_zero():
    """A bin with no live atom must come back as the untouched (inf, 0)
    accumulator on both the kernel and the oracle."""
    from repro.kernels.gls_race.kernel import gls_binned_race
    from repro.kernels.gls_race.ref import gls_binned_race_ref
    b, k, n, l_max = 2, 3, 256, 4
    key = jax.random.PRNGKey(7)
    log_s = jnp.log(jnp.maximum(jax.random.exponential(key, (b, k, n)),
                                1e-37))
    log_q = jax.random.normal(jax.random.fold_in(key, 1), (b, k, n))
    bins = jax.random.randint(jax.random.fold_in(key, 2), (b, n), 0, l_max)
    # Kill every atom of bin 2 (weight -inf), plus bin 3 has no atoms.
    log_q = jnp.where((bins == 2)[:, None, :], -jnp.inf, log_q)
    bins = jnp.where(bins == 3, 0, bins)
    for fn in (gls_binned_race, gls_binned_race_ref):
        bmin, barg = fn(log_s, log_q, bins, l_max=l_max)
        assert np.isinf(np.asarray(bmin[:, :, 2])).all()
        assert (np.asarray(barg[:, :, 2]) == 0).all()
        assert np.isinf(np.asarray(bmin[:, :, 3])).all()
        assert (np.asarray(barg[:, :, 3]) == 0).all()
        assert np.isfinite(np.asarray(bmin[:, :, :2])).all()


def test_gls_race_zero_prob_symbols_never_win():
    b, k, n = 2, 3, 256
    key = jax.random.PRNGKey(0)
    u = jax.random.uniform(key, (b, k, n), minval=1e-30, maxval=1.0)
    log_s = jnp.log(-jnp.log(u))
    p = jax.random.dirichlet(key, jnp.ones(n), (b, k))
    p = p.at[..., :128].set(0.0)
    p = p / p.sum(-1, keepdims=True)
    log_p = jnp.where(p > 0, jnp.log(jnp.maximum(p, 1e-37)), -jnp.inf)
    x, y = gls_race(log_s, log_p, log_p, jnp.ones((b, k), bool), tile_n=128,
                    interpret=True)
    assert bool(jnp.all(x >= 128)) and bool(jnp.all(y >= 128))


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,hkv,s,t,d,causal,window", [
    (1, 4, 4, 128, 128, 64, True, 0),
    (2, 8, 2, 256, 256, 64, True, 0),     # GQA
    (1, 4, 1, 192, 192, 128, True, 64),   # MQA + sliding window
    (1, 2, 2, 100, 100, 64, True, 0),     # non-multiple-of-tile seq
    (1, 4, 4, 64, 256, 64, False, 0),     # cross-attention shape
])
def test_flash_attention_matches_ref(b, h, hkv, s, t, d, causal, window,
                                     dtype):
    key = jax.random.PRNGKey(s + t)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, h, s, d), dtype)
    k = jax.random.normal(kk, (b, hkv, t, d), dtype)
    v = jax.random.normal(kv, (b, hkv, t, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          tq=64, tk=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_per_row_offsets_match_ref():
    """Arena-prefill masking (DESIGN.md §9): per-row q_offset/kv_len in
    the kernel == the jnp oracle == the dense layers.attention path."""
    from repro.models import layers as L
    key = jax.random.PRNGKey(9)
    b, h, hkv, s, t, d = 5, 4, 2, 24, 96, 32
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, h, s, d), jnp.float32)
    k = jax.random.normal(kk, (b, hkv, t, d), jnp.float32)
    v = jax.random.normal(kv, (b, hkv, t, d), jnp.float32)
    q_off = jnp.array([0, 3, 17, 40, 72], jnp.int32)
    kv_len = q_off + s
    out = flash_attention(q, k, v, q_off, kv_len, causal=True, tq=16,
                          tk=32, interpret=True)
    ref = flash_attention_ref(q, k, v, q_off, kv_len, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    dense = L.attention(q, k, v, causal=True, q_offset=q_off, kv_len=kv_len)
    routed = L.attention(q, k, v, causal=True, q_offset=q_off,
                         kv_len=kv_len, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(routed), np.asarray(dense),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_fully_masked_rows_emit_zeros():
    """Bucket-pad rows (kv_len == 0) must come back as zeros, not NaN."""
    key = jax.random.PRNGKey(3)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (2, 2, 8, 16), jnp.float32)
    k = jax.random.normal(kk, (2, 2, 32, 16), jnp.float32)
    v = jax.random.normal(kv, (2, 2, 32, 16), jnp.float32)
    kv_len = jnp.array([0, 32], jnp.int32)
    out = np.asarray(flash_attention(q, k, v, None, kv_len, causal=True,
                                     tq=8, tk=8, interpret=True))
    assert np.isfinite(out).all()
    assert (out[0] == 0.0).all()
    assert (np.abs(out[1]) > 0).any()


def test_masked_row_policy_ref_and_kernel_agree_bitwise():
    """Satellite (DESIGN.md §13): ref.py's ``masked_softmax`` and the
    kernel share one masked-row contract — fully-masked rows (kv_len 0,
    or every score windowed out to -inf) emit EXACTLY 0.0 in both
    paths, with no NaN-then-scrub step.  The old reference scrubbed
    ``isnan`` after softmax while the kernel guarded its running max
    with ``isfinite``; this pins their bitwise agreement on every
    masked-row shape."""
    from repro.kernels.flash_attention.ref import masked_softmax
    key = jax.random.PRNGKey(5)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (3, 2, 8, 16), jnp.float32)
    k = jax.random.normal(kk, (3, 2, 16, 16), jnp.float32)
    v = jax.random.normal(kv, (3, 2, 16, 16), jnp.float32)
    # Row 0: kv_len == 0 (bucket padding).  Row 1: sliding window with
    # q_offset far past kv_len — every key is simultaneously below the
    # window and beyond kv_len, so all 8 query rows score -inf
    # everywhere.  Row 2: ordinary.
    q_off = jnp.array([0, 12, 0], jnp.int32)
    kv_len = jnp.array([0, 3, 16], jnp.int32)
    out = np.asarray(flash_attention(q, k, v, q_off, kv_len, causal=True,
                                     window=2, tq=8, tk=8,
                                     interpret=True))
    ref = np.asarray(flash_attention_ref(q, k, v, q_off, kv_len,
                                         causal=True, window=2))
    assert np.isfinite(ref).all() and np.isfinite(out).all()
    assert (ref[0] == 0.0).all() and (out[0] == 0.0).all()
    assert (ref[1] == 0.0).all() and (out[1] == 0.0).all()
    assert (np.abs(ref[2]) > 0).any()
    # Masked rows agree BITWISE (exact zeros on both sides).
    np.testing.assert_array_equal(out[:2], ref[:2])
    # masked_softmax on a fully-masked row: all-zero weights, and on
    # rows with >= 1 valid entry it is bitwise jax.nn.softmax of the
    # -inf-masked scores (the 1e-30 denominator floor is inert).
    scores = jax.random.normal(key, (4, 6), jnp.float32)
    mask = jnp.array([[True] * 6, [False] * 6,
                      [True] + [False] * 5, [False, True] + [True] * 4])
    w = np.asarray(masked_softmax(scores, mask))
    assert (w[1] == 0.0).all()
    dense = np.asarray(jax.nn.softmax(
        jnp.where(mask, scores, -jnp.inf), axis=-1))
    np.testing.assert_array_equal(w[[0, 2, 3]], dense[[0, 2, 3]])


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,hkv,t,d,tk", [
    (1, 4, 4, 128, 64, 128),
    (2, 8, 2, 512, 64, 128),
    (4, 16, 1, 300, 128, 128),   # MQA, ragged cache length
])
def test_decode_attention_matches_ref(b, h, hkv, t, d, tk, dtype):
    key = jax.random.PRNGKey(t)
    kq, kk, kv, kl = jax.random.split(key, 4)
    q = jax.random.normal(kq, (b, h, d), dtype)
    k = jax.random.normal(kk, (b, hkv, t, d), dtype)
    v = jax.random.normal(kv, (b, hkv, t, d), dtype)
    kv_len = jax.random.randint(kl, (b,), 1, t + 1)
    out = decode_attention(q, k, v, kv_len, tk=tk, interpret=True)
    ref = decode_attention_ref(q, k, v, kv_len)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_decode_attention_single_valid_token():
    b, h, hkv, t, d = 1, 2, 1, 64, 32
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, hkv, t, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, hkv, t, d))
    kv_len = jnp.asarray([1])
    out = decode_attention(q, k, v, kv_len, tk=32, interpret=True)
    # With one valid token, output == v[:, :, 0] broadcast over groups.
    np.testing.assert_allclose(np.asarray(out[0, 0]), np.asarray(v[0, 0, 0]),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# Slot-aware decode path through the decode-attention kernel
# ---------------------------------------------------------------------------


def test_decode_step_slots_use_kernel_matches_dense_path():
    """The Pallas decode-attention kernel behind ``use_kernel`` must be
    numerically equivalent (online-softmax reduction order — allclose,
    not bit-equal) to the dense slot-aware decode, per-row positions
    included."""
    from repro.models import ModelConfig, init_cache, init_params
    from repro.models.transformer import decode_step_slots

    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=64, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, t = 6, 32
    cache = init_cache(cfg, b, t)
    cache = {"k": jax.random.normal(jax.random.PRNGKey(1),
                                    cache["k"].shape),
             "v": jax.random.normal(jax.random.PRNGKey(2),
                                    cache["v"].shape)}
    tokens = jax.random.randint(jax.random.PRNGKey(3), (b, 1), 0, 64)
    pos = jnp.asarray([0, 3, 7, 12, 25, 31], jnp.int32)  # per-row ragged

    ref_logits, ref_cache = decode_step_slots(params, cfg, tokens, cache,
                                              pos)
    ker_logits, ker_cache = decode_step_slots(params, cfg, tokens, cache,
                                              pos, use_kernel=True,
                                              interpret=True)
    np.testing.assert_allclose(np.asarray(ker_logits),
                               np.asarray(ref_logits), atol=2e-5,
                               rtol=2e-5)
    # Deeper layers' K/V projections consume earlier layers' attention
    # outputs, so caches inherit the kernel's reduction-order ulps —
    # equivalent, not bit-equal.
    np.testing.assert_allclose(np.asarray(ker_cache["k"]),
                               np.asarray(ref_cache["k"]), atol=2e-5,
                               rtol=2e-5)
    np.testing.assert_allclose(np.asarray(ker_cache["v"]),
                               np.asarray(ref_cache["v"]), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# Chunked-attention layer vs flash kernel (the jnp twin used inside models)
# ---------------------------------------------------------------------------


def test_model_chunked_attention_matches_kernel():
    from repro.models.layers import chunked_attention
    key = jax.random.PRNGKey(3)
    kq, kk, kv = jax.random.split(key, 3)
    b, h, hkv, s, d = 1, 4, 2, 256, 64
    q = jax.random.normal(kq, (b, h, s, d))
    k = jax.random.normal(kk, (b, hkv, s, d))
    v = jax.random.normal(kv, (b, hkv, s, d))
    a = chunked_attention(q, k, v, causal=True, kv_block=64)
    bref = flash_attention(q, k, v, causal=True, tq=64, tk=64,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# Execution-mode resolution (DESIGN.md §11)
# ---------------------------------------------------------------------------


def test_default_mode_resolution_matches_backend():
    """interpret=None compiles where Pallas lowers (TPU/GPU) and falls
    back to the reference elsewhere; True/False force their modes."""
    from repro.kernels.pallas_mode import has_compiled_pallas, \
        resolve_pallas_mode
    expected = "compiled" if has_compiled_pallas() else "fallback"
    assert resolve_pallas_mode(None) == expected
    assert resolve_pallas_mode(True) == "interpret"
    assert resolve_pallas_mode(False) == "compiled"


@pytest.mark.parametrize("b,k,n,l_max", [
    (3, 4, 500, 4),
    (9, 3, 2 ** 14, 4),   # the wz-pipeline shape class
])
def test_gls_binned_race_default_mode_bit_identical(b, k, n, l_max):
    """Default-mode gls_binned_race must be BIT-identical to the oracle on
    every backend: compiled lowering on TPU/GPU is exactness-tested by
    the interpret sweep above; the CPU fallback IS the oracle."""
    from repro.kernels.gls_race.kernel import gls_binned_race
    from repro.kernels.gls_race.ref import gls_binned_race_ref
    key = jax.random.PRNGKey(b * 77 + n)
    ks, kq, kb = jax.random.split(key, 3)
    log_s = jnp.log(jnp.maximum(jax.random.exponential(ks, (b, k, n)),
                                1e-37))
    log_q = jnp.where(jax.random.bernoulli(kq, 0.8, (b, k, n)),
                      jax.random.normal(kq, (b, k, n)), -jnp.inf)
    bins = jax.random.randint(kb, (b, n), 0, l_max)
    bmin, barg = gls_binned_race(log_s, log_q, bins, l_max=l_max)
    bmin_r, barg_r = gls_binned_race_ref(log_s, log_q, bins, l_max=l_max)
    np.testing.assert_array_equal(np.asarray(bmin), np.asarray(bmin_r))
    np.testing.assert_array_equal(np.asarray(barg), np.asarray(barg_r))


def test_gls_row_race_default_mode_bit_identical():
    from repro.kernels.gls_race.ref import gls_row_race_ref as row_ref
    key = jax.random.PRNGKey(42)
    ku, kq = jax.random.split(key)
    b, k, n = 7, 4, 1000
    u = jax.random.uniform(ku, (b, k, n), minval=1e-30, maxval=1.0)
    log_s = jnp.log(-jnp.log(u))
    log_q = jax.random.normal(kq, (b, k, n))
    rmin, rarg = gls_row_race(log_s, log_q)
    rmin_r, rarg_r = row_ref(log_s, log_q)
    np.testing.assert_array_equal(np.asarray(rmin), np.asarray(rmin_r))
    np.testing.assert_array_equal(np.asarray(rarg), np.asarray(rarg_r))
