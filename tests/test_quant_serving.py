"""W8A8 int8 serving path (§Perf B4): quantized verify_step must agree
with the bf16 path on top-1 tokens and stay within a small relative
logit error; the quantizer round-trips weights within int8 resolution."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, init_cache, init_params, prefill
from repro.models.transformer import verify_step
from repro.serving import qdot, quantize_params, quantize_weight, verify_step_q


def test_quantize_weight_roundtrip():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 0.3
    q = quantize_weight(w)
    deq = q["q"].astype(jnp.float32) * q["s"]
    # max error bounded by half a quantization step per channel
    step = np.asarray(q["s"])
    err = np.abs(np.asarray(deq) - np.asarray(w))
    assert (err <= 0.51 * step[None, :]).all()


def test_qdot_matches_float_dot():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (4, 64))
    w = jax.random.normal(jax.random.fold_in(key, 1), (64, 32)) * 0.2
    got = qdot(x, quantize_weight(w))
    ref = x @ w
    rel = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))
    assert rel < 0.05, rel


def test_verify_step_q_top1_agreement():
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=128,
                      num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256,
                      vocab_size=256, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    pq = quantize_params(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 200)
    cache = init_cache(cfg, 2, 64)
    _, c1 = prefill(params, cfg, {"tokens": toks[:, :6]}, cache)
    c2 = jax.tree.map(lambda a: a, c1)
    ref, _ = verify_step(params, cfg, toks[:, 6:11], c1)
    got, _ = verify_step_q(pq, cfg, toks[:, 6:11], c2)
    top_ref = jnp.argmax(ref[..., :cfg.vocab_size], -1)
    top_got = jnp.argmax(got[..., :cfg.vocab_size], -1)
    assert float(jnp.mean(top_ref == top_got)) >= 0.9
    rel = float(jnp.mean(jnp.abs(ref - got)) / jnp.mean(jnp.abs(ref)))
    assert rel < 0.1, rel
