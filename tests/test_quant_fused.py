"""Quantized serving tests (DESIGN.md §11): int8 KV cache-pool arena
mechanics (quantize-on-install, bit-exact row replication and buffer
growth, per-vector dequant error bound), dequant-in-kernel attention
reads, and the gate that matters — quantized-vs-bf16 ACCEPTANCE-RATE
equivalence across all six verification strategies (quantization moves
logits by design, so bit-identity is the wrong contract; the coupling
quality the paper measures is acceptance)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import CachePool, ModelConfig, init_cache, init_params
from repro.serving.quant import dequantize_kv, quantize_kv
from repro.specdec.block_verify import RACE_STRATEGIES, RS_STRATEGIES
from repro.specdec.engine import SpecDecConfig
from repro.specdec.engine_cached import CachedSpecDecEngine


T_CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                    vocab_size=64, dtype="float32")
D_CFG = dataclasses.replace(T_CFG, name="d", d_model=32, d_ff=64,
                            num_heads=2, num_kv_heads=1)


def _quant_pool(buf=16, slots=3, rows=2):
    return CachePool({"target": T_CFG, "drafter": D_CFG}, num_slots=slots,
                     rows_per_slot=rows, buf_len=buf, quant=True)


# ---------------------------------------------------------------------------
# quantize_kv / dequantize_kv
# ---------------------------------------------------------------------------


def test_quantize_kv_roundtrip_error_bounded_per_vector():
    """|dequant(quantize(x)) - x| <= scale/2 elementwise, with scale the
    per-KV-vector max-abs/127 — the §11 arena error contract."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 4, 17, 8))
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8 and s.shape == x.shape[:-1] + (1,)
    err = np.abs(np.asarray(dequantize_kv(q, s)) - np.asarray(x))
    bound = 0.5 * np.asarray(s) + 1e-7
    assert (err <= bound).all()
    # Scales are strictly positive (1e-8 floor) even for all-zero vectors.
    z_q, z_s = quantize_kv(jnp.zeros((1, 4)))
    assert (np.asarray(z_q) == 0).all() and (np.asarray(z_s) > 0).all()


def test_quantize_kv_exact_for_representable_values():
    """Values already on the int8 grid survive the round trip exactly."""
    ints = jax.random.randint(jax.random.PRNGKey(1), (3, 5, 8), -127, 128)
    x = ints.astype(jnp.float32) * 0.03
    # Force a known scale by planting max magnitude 127 in every vector.
    x = x.at[..., 0].set(127 * 0.03)
    q, s = quantize_kv(x)
    np.testing.assert_allclose(np.asarray(dequantize_kv(q, s)),
                               np.asarray(x), rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# int8 cache-pool arena mechanics
# ---------------------------------------------------------------------------


def test_quant_pool_arena_layout_and_prefill_install():
    """Quant pools hold 4-leaf arenas; ``write_prefill`` quantizes a
    dense prefill cache on install, bit-exact against quantize_kv."""
    pool = _quant_pool()
    for arena in pool.caches.values():
        assert set(arena) == {"k", "v", "k_s", "v_s"}
        assert arena["k"].dtype == jnp.int8
        assert arena["k_s"].shape == arena["k"].shape[:-1] + (1,)
    slot = pool.alloc()
    cache = init_cache(T_CFG, pool.rows_per_slot, pool.buf_len)
    cache = {"k": jax.random.normal(jax.random.PRNGKey(2),
                                    cache["k"].shape),
             "v": jax.random.normal(jax.random.PRNGKey(3),
                                    cache["v"].shape)}
    pool.write_prefill("target", slot, cache, pos=5)
    rows = pool.rows_of(slot)
    kq, ks = quantize_kv(cache["k"])
    arena = pool.caches["target"]
    np.testing.assert_array_equal(np.asarray(arena["k"][:, rows]),
                                  np.asarray(kq))
    np.testing.assert_array_equal(np.asarray(arena["k_s"][:, rows]),
                                  np.asarray(ks))


def test_quant_pool_rollback_and_growth_bit_exact():
    """Row replication (rollback) and ensure_buf growth are index/copy
    ops — on a quant pool they must move int8 payloads AND their scales
    identically, bit for bit."""
    pool = _quant_pool(buf=8, slots=2, rows=2)
    slot = pool.alloc()
    cache = init_cache(T_CFG, pool.rows_per_slot, pool.buf_len)
    cache = {"k": jax.random.normal(jax.random.PRNGKey(4),
                                    cache["k"].shape),
             "v": jax.random.normal(jax.random.PRNGKey(5),
                                    cache["v"].shape)}
    pool.write_prefill("target", slot, cache, pos=3)
    before = {kk: np.asarray(v) for kk, v in pool.caches["target"].items()}

    # Replicate row 1 of the slot across both its rows.
    rows = pool.rows_of(slot)
    row_src = np.arange(pool.num_slots * pool.rows_per_slot)
    row_src[rows] = rows[1]
    pool.rollback_rows(row_src)
    after = pool.caches["target"]
    for kk in before:
        np.testing.assert_array_equal(np.asarray(after[kk][:, rows[0]]),
                                      before[kk][:, rows[1]])

    # Growth preserves every live leaf bit-exactly in the old prefix.
    grown = {kk: np.asarray(v) for kk, v in after.items()}
    pool.ensure_buf(32)
    for kk, v in pool.caches["target"].items():
        assert v.shape[3] == 32
        np.testing.assert_array_equal(np.asarray(v[:, :, :, :8]), grown[kk])
        assert not np.asarray(v[:, :, :, 8:]).any()


# ---------------------------------------------------------------------------
# dequant-in-kernel attention reads
# ---------------------------------------------------------------------------


def test_attention_kernels_dequantize_in_kernel():
    """The interpret-mode kernels (same body that compiles on TPU/GPU)
    must match the scale-aware references on int8 KV + scales."""
    from repro.kernels.decode_attention.kernel import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    from repro.kernels.flash_attention.kernel import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    key = jax.random.PRNGKey(6)
    b, h, hkv, t, d, s = 3, 4, 2, 40, 16, 6
    kd = jax.random.normal(jax.random.fold_in(key, 1), (b, hkv, t, d))
    vd = jax.random.normal(jax.random.fold_in(key, 2), (b, hkv, t, d))
    k8, ks = quantize_kv(kd)
    v8, vs = quantize_kv(vd)
    q1 = jax.random.normal(jax.random.fold_in(key, 3), (b, h, d))
    kv_len = jnp.asarray([40, 11, 1], jnp.int32)
    out = decode_attention(q1, k8, v8, kv_len, ks, vs, tk=16,
                           interpret=True)
    ref = decode_attention_ref(q1, k8, v8, kv_len, ks, vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    # ...and the dequantized ref stays close to the unquantized one.
    exact = decode_attention_ref(q1, kd, vd, kv_len)
    assert np.max(np.abs(np.asarray(ref) - np.asarray(exact))) < 0.05

    qs = jax.random.normal(jax.random.fold_in(key, 4), (b, h, s, d))
    q_off = jnp.asarray([0, 5, 30], jnp.int32)
    fout = flash_attention(qs, k8, v8, q_off, q_off + s, ks, vs,
                           causal=True, tq=8, tk=16, interpret=True)
    fref = flash_attention_ref(qs, k8, v8, q_off, q_off + s, ks, vs,
                               causal=True)
    np.testing.assert_allclose(np.asarray(fout), np.asarray(fref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# acceptance-rate equivalence, all six strategies
# ---------------------------------------------------------------------------


def _acceptance(quant: bool, strategy: str, seeds=(11, 12, 13),
                max_new=32):
    kt, kd = jax.random.split(jax.random.PRNGKey(0))
    tp = init_params(kt, T_CFG)
    dp = init_params(kd, D_CFG)
    cfg = SpecDecConfig(num_drafts=2, draft_len=3, strategy=strategy,
                        quant=quant)
    eng = CachedSpecDecEngine((tp, T_CFG), (dp, D_CFG), cfg, pool_slots=1)
    prompt = np.arange(1, 9, dtype=np.int32)
    acc = blocks = 0
    for seed in seeds:
        st = eng.generate(jax.random.PRNGKey(seed), prompt,
                          max_new=max_new, fused=True)
        acc += st.accepted_drafts
        blocks += st.blocks
    return acc / (blocks * cfg.draft_len)


@pytest.mark.parametrize("strategy", RACE_STRATEGIES + RS_STRATEGIES)
def test_quant_acceptance_matches_bf16_all_strategies(strategy):
    """The §11 quantization gate: int8 KV arenas + W8A8 verify must not
    move the per-strategy acceptance rate beyond statistical tolerance.
    Shared RNG (same keys both runs) removes most sampling variance, so
    the residual gap is the quantization effect itself."""
    rate_f = _acceptance(False, strategy)
    rate_q = _acceptance(True, strategy)
    assert abs(rate_q - rate_f) <= 0.2, (
        f"{strategy}: quant acceptance {rate_q:.3f} vs bf16 {rate_f:.3f}")
