"""Arch-applicability (DESIGN.md §4): GLS speculative decoding is a
sampling-layer technique — it must work with ANY family as the target.
Run the engine with SSM, MoE and hybrid targets (dense drafter) and check
generation succeeds with sane block efficiency."""

import jax
import numpy as np
import pytest

from repro.models import ModelConfig, init_params
from repro.specdec import SpecDecConfig, SpecDecEngine

DRAFTER = ModelConfig(name="d", family="dense", num_layers=1, d_model=48,
                      num_heads=4, num_kv_heads=2, head_dim=12, d_ff=96,
                      vocab_size=64, dtype="float32")

TARGETS = {
    "ssm": ModelConfig(name="ts", family="ssm", num_layers=2, d_model=64,
                       num_heads=1, d_ff=0, vocab_size=64, ssm_state=16,
                       ssm_head_dim=32, ssm_chunk=8, dtype="float32"),
    "moe": ModelConfig(name="tm", family="moe", num_layers=2, d_model=64,
                       num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                       vocab_size=64, num_experts=4, experts_per_token=2,
                       dtype="float32"),
    "hybrid": ModelConfig(name="th", family="hybrid", num_layers=3,
                          d_model=64, num_heads=4, num_kv_heads=1,
                          head_dim=16, d_ff=128, vocab_size=64,
                          pattern_rec=2, local_window=16, lru_width=64,
                          dtype="float32"),
}


@pytest.mark.parametrize("family", list(TARGETS))
def test_gls_specdec_with_nondense_target(family):
    tcfg = TARGETS[family]
    tp = init_params(jax.random.PRNGKey(0), tcfg)
    dp = init_params(jax.random.PRNGKey(1), DRAFTER)
    eng = SpecDecEngine((tp, tcfg), [(dp, DRAFTER)],
                        SpecDecConfig(num_drafts=2, draft_len=2,
                                      strategy="gls", top_k=0,
                                      max_new_tokens=10))
    stats = eng.generate(jax.random.PRNGKey(5),
                         np.array([1, 2, 3], np.int32))
    assert len(stats.output) == 10
    assert 1.0 <= stats.block_efficiency <= 3.0
    assert (stats.output >= 0).all() and (stats.output < 64).all()
