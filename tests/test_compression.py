"""Lossy compression application tests (paper Sec. 5; DESIGN.md §10):
the per-sample oracle, the batched pipeline (xla↔pallas backend
interchangeability, single-dispatch contract, Prop.-4 match bound), and
the race RNG distribution."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compression import (
    GaussianWZ,
    make_bins,
    run_experiment,
    wz_pipeline,
    wz_round,
)
from repro.core import conditional_lml_bound, wz_error_upper_bound


def test_gaussian_matching_grows_with_rate_and_k():
    cfg = GaussianWZ(sigma2_w_given_a=0.01, n_atoms=1024)
    key = jax.random.PRNGKey(0)
    prev = 0.0
    for l_max in (2, 8, 32):
        r = run_experiment(key, cfg, k=2, l_max=l_max, trials=800)
        assert r["match_prob_any"] >= prev - 0.03
        prev = r["match_prob_any"]
    r1 = run_experiment(key, cfg, k=1, l_max=8, trials=800)
    r4 = run_experiment(key, cfg, k=4, l_max=8, trials=800)
    assert r4["match_prob_any"] > r1["match_prob_any"] + 0.05


def test_gls_beats_shared_baseline_multidecoder():
    cfg = GaussianWZ(sigma2_w_given_a=0.01, n_atoms=1024)
    key = jax.random.PRNGKey(1)
    gls = run_experiment(key, cfg, k=4, l_max=4, trials=800)
    base = run_experiment(key, cfg, k=4, l_max=4, trials=800,
                          shared_sheet=True)
    assert gls["match_prob_any"] > base["match_prob_any"] + 0.03
    assert gls["distortion"] < base["distortion"]


def test_k1_gls_equals_baseline():
    """For K=1 both schemes are the single-decoder IML — identical."""
    cfg = GaussianWZ(sigma2_w_given_a=0.01, n_atoms=512)
    key = jax.random.PRNGKey(2)
    a = run_experiment(key, cfg, k=1, l_max=8, trials=400)
    b = run_experiment(key, cfg, k=1, l_max=8, trials=400, shared_sheet=True)
    assert a["match_prob_any"] == pytest.approx(b["match_prob_any"], abs=1e-9)


def test_wz_error_bound_holds_discrete():
    """Proposition 4 on a discrete source where all densities are exact."""
    n, k, l_max = 64, 3, 4
    key = jax.random.PRNGKey(3)
    kq, kd, kb = jax.random.split(key, 3)
    # Discrete atoms: W uniform prior over n; encoder/decoder targets are
    # random but consistent: q_enc = p(w|a), q_dec_k = p(w|t_k).
    q_enc = jax.random.dirichlet(kq, jnp.ones(n))
    q_dec = jax.random.dirichlet(kd, jnp.ones(n), (k,))
    log_w_enc = jnp.log(q_enc * n)               # / uniform prior 1/n
    log_w_dec = jnp.log(q_dec * n)
    trials = 4000
    matches = []
    infos = []
    for i in range(trials):
        kk = jax.random.fold_in(key, i)
        kb_i, kr = jax.random.split(kk)
        bins = make_bins(kb_i, n, l_max)
        code = wz_round(kr, log_w_enc, log_w_dec, bins, k)
        matches.append(bool(jnp.any(code.match)))
        infos.append(float(jnp.log2(q_enc[code.y]
                                    / jnp.mean(q_dec[:, code.y]))))
    err = 1.0 - np.mean(matches)
    bound = float(wz_error_upper_bound(jnp.asarray(infos), k, l_max))
    # Prop. 4 is an upper bound on error (up to MC noise).
    assert err <= bound + 0.05, (err, bound)


def test_conditional_lml_shapes():
    b = conditional_lml_bound(jnp.asarray(0.3), jnp.asarray([0.2, 0.4]), 2)
    assert 0.0 < float(b) <= 1.0


def test_race_tables_exponential_distribution():
    """Regression pin for the ``_race_tables`` fix: race times must be
    finite log Exp(1) samples (the old tiny-clamped ``log(-log U)`` path
    truncated the upper tail and amplified rounding near u -> 1)."""
    from repro.compression.wz import _race_tables
    log_s = np.asarray(_race_tables(jax.random.PRNGKey(0), 4, 50_000))
    assert np.isfinite(log_s).all()
    s = np.exp(log_s).ravel()
    assert abs(s.mean() - 1.0) < 0.02          # E[Exp(1)] = 1
    assert abs(s.var() - 1.0) < 0.05           # Var[Exp(1)] = 1
    # Kolmogorov-Smirnov distance to the Exp(1) CDF (200k samples ->
    # KS noise ~0.003; 0.01 catches any clamping/truncation regression).
    srt = np.sort(s)
    emp = np.arange(1, srt.size + 1) / srt.size
    ks = np.abs(emp - (1.0 - np.exp(-srt))).max()
    assert ks < 0.01, ks


def _random_pipeline_inputs(key, b, k, n, l_max, dead_frac=0.1):
    kw, kd, kb, kr = jax.random.split(key, 4)
    log_w_enc = jax.random.normal(kw, (b, n))
    log_w_enc = jnp.where(jax.random.bernoulli(kw, 1 - dead_frac, (b, n)),
                          log_w_enc, -jnp.inf)
    log_w_dec = jax.random.normal(kd, (b, k, n))
    bins = jax.vmap(lambda kk: make_bins(kk, n, l_max))(
        jax.random.split(kb, b))
    return jax.random.split(kr, b), log_w_enc, log_w_dec, bins


@pytest.mark.parametrize("shared_sheet", [False, True])
def test_pipeline_matches_per_sample_oracle(shared_sheet):
    """The batched pipeline must reproduce the per-sample ``wz_round``
    oracle exactly on both backends: the vmapped race tables are
    per-lane bit-identical and the reformulated selection picks the same
    (continuous, tie-free) minima."""
    b, k, n, l_max = 48, 3, 1024, 8
    keys, log_w_enc, log_w_dec, bins = _random_pipeline_inputs(
        jax.random.PRNGKey(0), b, k, n, l_max)
    oracle = [wz_round(keys[i], log_w_enc[i], log_w_dec[i], bins[i], k,
                       shared_sheet=shared_sheet) for i in range(b)]
    for backend in ("xla", "pallas"):
        out = wz_pipeline(keys, log_w_enc, log_w_dec, bins, l_max=l_max,
                          shared_sheet=shared_sheet, backend=backend)
        np.testing.assert_array_equal(
            np.asarray(out.y), np.asarray([int(c.y) for c in oracle]))
        np.testing.assert_array_equal(
            np.asarray(out.message),
            np.asarray([int(c.message) for c in oracle]))
        np.testing.assert_array_equal(
            np.asarray(out.x), np.stack([np.asarray(c.x) for c in oracle]))
        np.testing.assert_array_equal(
            np.asarray(out.match),
            np.stack([np.asarray(c.match) for c in oracle]))


def test_pipeline_backends_bit_equal_large():
    """The acceptance-bar shape: B >= 256 rounds over N >= 2^14 atoms
    must come out EXACTLY equal on the xla and pallas backends (the
    kernel tiles the atom axis through fixed VMEM; the oracle reduces in
    one sweep — identical score floats either way)."""
    b, k, n, l_max = 256, 2, 2 ** 14, 4
    keys, log_w_enc, log_w_dec, bins = _random_pipeline_inputs(
        jax.random.PRNGKey(1), b, k, n, l_max)
    out_x = wz_pipeline(keys, log_w_enc, log_w_dec, bins, l_max=l_max,
                        backend="xla")
    out_p = wz_pipeline(keys, log_w_enc, log_w_dec, bins, l_max=l_max,
                        backend="pallas", tile_n=8192)
    for got, want in zip(out_p, out_x):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pipeline_one_kernel_dispatch_per_batch():
    """The single-dispatch contract, per execution mode (DESIGN.md §11):
    a compiled/interpret pallas batch embeds exactly ONE
    ``gls_binned_race`` call; the CPU fallback re-sequences through TWO
    ``gls_row_race`` dispatches (encoder + bin-masked decoder) and no
    binned dispatch.  Either way, re-running the compiled program
    dispatches nothing new at trace level (trace-time counters in
    kernels/gls_race/ops.py)."""
    from repro.kernels.gls_race import ops
    # Unique static/shape combo so this test owns its trace.
    b, k, n, l_max = 17, 3, 384, 5
    keys, log_w_enc, log_w_dec, bins = _random_pipeline_inputs(
        jax.random.PRNGKey(2), b, k, n, l_max)
    fallback = ops.resolve_race_mode(None) == "fallback"
    expect = ({"row_race_pallas": 2} if fallback
              else {"binned_race_pallas": 1})
    ops.reset_dispatch_counts()
    for _ in range(2):      # second run: cached program, no new traces
        out = wz_pipeline(keys, log_w_enc, log_w_dec, bins, l_max=l_max,
                          backend="pallas")
        jax.block_until_ready(out)
        for kk, cnt in expect.items():
            assert ops.dispatch_counts[kk] == cnt, dict(ops.dispatch_counts)
    if fallback:
        assert ops.dispatch_counts["binned_race_pallas"] == 0

    # The kernel-structure contract stays pinned regardless of backend:
    # interpret mode forces the single binned-race program.
    ops.reset_dispatch_counts()
    out = wz_pipeline(keys, log_w_enc, log_w_dec, bins, l_max=l_max,
                      backend="pallas", interpret=True)
    jax.block_until_ready(out)
    assert ops.dispatch_counts["binned_race_pallas"] == 1, \
        dict(ops.dispatch_counts)


@pytest.mark.parametrize("k,l_max", [(1, 2), (2, 2), (2, 8), (4, 8)])
def test_gaussian_match_rate_meets_prop4_bound(k, l_max):
    """List-matching-lemma coverage on the compression path: the
    empirical any-decoder match rate of the batched pipeline must meet
    the Prop.-4 lower bound computed from the same trials' information
    densities (core/bounds.wz_error_upper_bound), across K and l_max."""
    cfg = GaussianWZ(sigma2_w_given_a=0.01, n_atoms=1024)
    r = run_experiment(jax.random.PRNGKey(3), cfg, k, l_max, trials=800)
    assert r["match_prob_any"] >= r["match_lower_bound"] - 0.05, r


def test_run_experiment_backends_agree():
    """xla and pallas pipeline backends must report identical Gaussian
    experiment statistics (same trials, same races, same selections)."""
    cfg = GaussianWZ(sigma2_w_given_a=0.01, n_atoms=512)
    key = jax.random.PRNGKey(4)
    a = run_experiment(key, cfg, k=2, l_max=4, trials=96, backend="xla")
    b = run_experiment(key, cfg, k=2, l_max=4, trials=96, backend="pallas")
    assert a == b


def test_vae_pipeline_end_to_end_small():
    from repro.compression import VAETrainConfig, train_vae, evaluate_rd
    from repro.data.mnist import digits_dataset
    imgs, _ = digits_dataset(400, seed=0)
    params = train_vae(jax.random.PRNGKey(0), imgs,
                       VAETrainConfig(steps=40, beta=0.35),
                       log=lambda *_: None)
    r = evaluate_rd(jax.random.PRNGKey(1), params, imgs, n_atoms=64,
                    l_max=8, k=2, trials=8)
    assert 0.0 <= r["match_prob_any"] <= 1.0
    assert np.isfinite(r["mse"])
