"""Lossy compression application tests (paper Sec. 5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compression import GaussianWZ, run_experiment, wz_round, make_bins
from repro.core import conditional_lml_bound, wz_error_upper_bound


def test_gaussian_matching_grows_with_rate_and_k():
    cfg = GaussianWZ(sigma2_w_given_a=0.01, n_atoms=1024)
    key = jax.random.PRNGKey(0)
    prev = 0.0
    for l_max in (2, 8, 32):
        r = run_experiment(key, cfg, k=2, l_max=l_max, trials=800)
        assert r["match_prob_any"] >= prev - 0.03
        prev = r["match_prob_any"]
    r1 = run_experiment(key, cfg, k=1, l_max=8, trials=800)
    r4 = run_experiment(key, cfg, k=4, l_max=8, trials=800)
    assert r4["match_prob_any"] > r1["match_prob_any"] + 0.05


def test_gls_beats_shared_baseline_multidecoder():
    cfg = GaussianWZ(sigma2_w_given_a=0.01, n_atoms=1024)
    key = jax.random.PRNGKey(1)
    gls = run_experiment(key, cfg, k=4, l_max=4, trials=800)
    base = run_experiment(key, cfg, k=4, l_max=4, trials=800,
                          shared_sheet=True)
    assert gls["match_prob_any"] > base["match_prob_any"] + 0.03
    assert gls["distortion"] < base["distortion"]


def test_k1_gls_equals_baseline():
    """For K=1 both schemes are the single-decoder IML — identical."""
    cfg = GaussianWZ(sigma2_w_given_a=0.01, n_atoms=512)
    key = jax.random.PRNGKey(2)
    a = run_experiment(key, cfg, k=1, l_max=8, trials=400)
    b = run_experiment(key, cfg, k=1, l_max=8, trials=400, shared_sheet=True)
    assert a["match_prob_any"] == pytest.approx(b["match_prob_any"], abs=1e-9)


def test_wz_error_bound_holds_discrete():
    """Proposition 4 on a discrete source where all densities are exact."""
    n, k, l_max = 64, 3, 4
    key = jax.random.PRNGKey(3)
    kq, kd, kb = jax.random.split(key, 3)
    # Discrete atoms: W uniform prior over n; encoder/decoder targets are
    # random but consistent: q_enc = p(w|a), q_dec_k = p(w|t_k).
    q_enc = jax.random.dirichlet(kq, jnp.ones(n))
    q_dec = jax.random.dirichlet(kd, jnp.ones(n), (k,))
    log_w_enc = jnp.log(q_enc * n)               # / uniform prior 1/n
    log_w_dec = jnp.log(q_dec * n)
    trials = 4000
    matches = []
    infos = []
    for i in range(trials):
        kk = jax.random.fold_in(key, i)
        kb_i, kr = jax.random.split(kk)
        bins = make_bins(kb_i, n, l_max)
        code = wz_round(kr, log_w_enc, log_w_dec, bins, k)
        matches.append(bool(jnp.any(code.match)))
        infos.append(float(jnp.log2(q_enc[code.y]
                                    / jnp.mean(q_dec[:, code.y]))))
    err = 1.0 - np.mean(matches)
    bound = float(wz_error_upper_bound(jnp.asarray(infos), k, l_max))
    # Prop. 4 is an upper bound on error (up to MC noise).
    assert err <= bound + 0.05, (err, bound)


def test_conditional_lml_shapes():
    b = conditional_lml_bound(jnp.asarray(0.3), jnp.asarray([0.2, 0.4]), 2)
    assert 0.0 < float(b) <= 1.0


def test_vae_pipeline_end_to_end_small():
    from repro.compression import VAETrainConfig, train_vae, evaluate_rd
    from repro.data.mnist import digits_dataset
    imgs, _ = digits_dataset(400, seed=0)
    params = train_vae(jax.random.PRNGKey(0), imgs,
                       VAETrainConfig(steps=40, beta=0.35),
                       log=lambda *_: None)
    r = evaluate_rd(jax.random.PRNGKey(1), params, imgs, n_atoms=64,
                    l_max=8, k=2, trials=8)
    assert 0.0 <= r["match_prob_any"] <= 1.0
    assert np.isfinite(r["mse"])
