"""App. C importance-sampling GLS: continuous targets via weighted atoms.

* race invariance to weight normalization (argmin of S/λ is scale-free);
* encoder output distribution converges to the target as N grows
  (atoms from the prior, weights = target/prior density ratio);
* masked atoms (-inf weights) never win.
Also: the chunked cross-entropy equals the monolithic CE exactly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gls_importance_sample


def _log_normal(x, mu, var):
    return -0.5 * (jnp.log(2 * jnp.pi * var) + (x - mu) ** 2 / var)


def test_race_invariant_to_normalization():
    n, k = 128, 3
    key = jax.random.PRNGKey(0)
    kw, kr = jax.random.split(key)
    log_w_q = jax.random.normal(kw, (n,))
    log_w_p = jax.random.normal(jax.random.fold_in(kw, 1), (k, n))
    a = gls_importance_sample(kr, log_w_q, log_w_p, k)
    b = gls_importance_sample(kr, log_w_q + 3.7, log_w_p - 1.2, k)
    assert int(a.y) == int(b.y)
    np.testing.assert_array_equal(np.asarray(a.x), np.asarray(b.x))


def test_encoder_marginal_converges_to_target():
    """Atoms U_i ~ N(0,1) prior; target N(1, 0.25).  The selected atom's
    empirical distribution must approach the target as N grows."""
    k = 1
    n = 4096
    trials = 3000
    mu_t, var_t = 1.0, 0.25

    def one(kk):
        ka, kr = jax.random.split(kk)
        atoms = jax.random.normal(ka, (n,))
        log_w = _log_normal(atoms, mu_t, var_t) - _log_normal(atoms, 0.0, 1.0)
        out = gls_importance_sample(kr, log_w, log_w[None, :], k)
        return atoms[out.y]

    keys = jax.random.split(jax.random.PRNGKey(1), trials)
    samples = np.asarray(jax.vmap(one)(keys))
    assert abs(samples.mean() - mu_t) < 0.05
    assert abs(samples.var() - var_t) < 0.06


def test_masked_atoms_never_selected():
    n, k = 64, 2
    key = jax.random.PRNGKey(2)
    log_w_q = jax.random.normal(key, (n,))
    log_w_p = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    # Mask the first half for the decoders (bin mismatch 1{l_i != M}).
    log_w_p = log_w_p.at[:, :32].set(-jnp.inf)
    out = gls_importance_sample(jax.random.fold_in(key, 2), log_w_q,
                                log_w_p, k)
    assert bool(jnp.all(out.x >= 32))


def test_chunked_ce_equals_monolithic():
    from repro.train.loop import chunked_ce, _masked_ce_terms
    b, s, d, v = 2, 64, 32, 50
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (b, s, d))
    head = jax.random.normal(jax.random.fold_in(key, 1), (d, 64))
    tgt = jax.random.randint(jax.random.fold_in(key, 2), (b, s), 0, v)
    nll_c, zz_c = chunked_ce(x, head, tgt, v, chunk=16)
    nll_m, zz_m = _masked_ce_terms(x @ head, tgt, v)
    np.testing.assert_allclose(float(nll_c), float(nll_m) / (b * s),
                               rtol=1e-5)
    np.testing.assert_allclose(float(zz_c), float(zz_m) / (b * s),
                               rtol=1e-5)
    # Gradients must match too (the chunked path is rematerialized).
    g1 = jax.grad(lambda xx: chunked_ce(xx, head, tgt, v, chunk=16)[0])(x)
    g2 = jax.grad(
        lambda xx: _masked_ce_terms(xx @ head, tgt, v)[0] / (b * s))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)
