"""Per-architecture smoke tests: instantiate a REDUCED variant of each
assigned architecture's family (<=2-3 layers, d_model<=256, <=4 experts)
and run one forward step + one serving step on CPU, asserting output
shapes and absence of NaNs.  Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_params,
    prefill,
)

BATCH, SEQ = 2, 32


def _batch_for(cfg, key):
    k1, k2 = jax.random.split(key)
    toks = jax.random.randint(k1, (BATCH, SEQ), 0, cfg.vocab_size - 1)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(k2, (BATCH, SEQ, cfg.d_model),
                                            cfg.activation_dtype)
        batch["tokens"] = toks[:, : cfg.max_decoder_len]
    if cfg.family == "vlm":
        batch["images"] = jax.random.normal(
            k2, (BATCH, cfg.num_image_tokens, cfg.d_model),
            cfg.activation_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_forward_and_serve(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = _batch_for(cfg, jax.random.PRNGKey(1))

    logits = forward(params, cfg, batch, remat=False)
    s_dec = batch["tokens"].shape[1]
    assert logits.shape == (BATCH, s_dec, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaN in forward"

    cache = init_cache(cfg, BATCH, 128)
    last, cache = prefill(params, cfg, batch, cache)
    assert last.shape == (BATCH, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(last))), f"{arch}: NaN in prefill"

    tok = jnp.zeros((BATCH, 1), jnp.int32)
    step_logits, cache = decode_step(params, cfg, tok, cache)
    assert step_logits.shape == (BATCH, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(step_logits))), f"{arch}: NaN in decode"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_train_step(arch):
    """One gradient step must produce finite grads for every family."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = _batch_for(cfg, jax.random.PRNGKey(1))
    targets = batch["tokens"]

    def loss_fn(p):
        logits = forward(p, cfg, batch, remat=False).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits[:, :-1, : cfg.vocab_size])
        tgt = targets[:, 1:]
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)
        return jnp.mean(nll)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), (
        f"{arch}: non-finite grads")


def test_exact_published_dims():
    """The full configs must carry the exact assigned dimensions."""
    expect = {
        "whisper-small": dict(num_layers=12, d_model=768, num_heads=12,
                              num_kv_heads=12, d_ff=3072, vocab_size=51865),
        "granite-8b": dict(num_layers=36, d_model=4096, num_heads=32,
                           num_kv_heads=8, d_ff=14336, vocab_size=49152),
        "llama-3.2-vision-11b": dict(num_layers=40, d_model=4096,
                                     num_heads=32, num_kv_heads=8,
                                     d_ff=14336, vocab_size=128256),
        "mamba2-370m": dict(num_layers=48, d_model=1024, d_ff=0,
                            vocab_size=50280, ssm_state=128),
        "granite-moe-1b-a400m": dict(num_layers=24, d_model=1024,
                                     num_heads=16, num_kv_heads=8, d_ff=512,
                                     vocab_size=49155, num_experts=32,
                                     experts_per_token=8),
        "llama3-405b": dict(num_layers=126, d_model=16384, num_heads=128,
                            num_kv_heads=8, d_ff=53248, vocab_size=128256),
        "mixtral-8x22b": dict(num_layers=56, d_model=6144, num_heads=48,
                              num_kv_heads=8, d_ff=16384, vocab_size=32768,
                              num_experts=8, experts_per_token=2),
        "smollm-360m": dict(num_layers=32, d_model=960, num_heads=15,
                            num_kv_heads=5, d_ff=2560, vocab_size=49152),
        "recurrentgemma-2b": dict(num_layers=26, d_model=2560, num_heads=10,
                                  num_kv_heads=1, d_ff=7680,
                                  vocab_size=256000),
        "granite-34b": dict(num_layers=88, d_model=6144, num_heads=48,
                            num_kv_heads=1, d_ff=24576, vocab_size=49152),
    }
    for arch, dims in expect.items():
        cfg = get_config(arch)
        for field, val in dims.items():
            assert getattr(cfg, field) == val, (arch, field)
