"""Fused block verification equivalence suite.

The fused device-side verifier (block_verify.py) must be a DROP-IN for
the legacy per-token host loop: bit-identical token sequences for all six
strategies under shared randomness, across backends ("xla" jnp fallback
vs "pallas" gls_race row kernel), and through every serving layer
(reference engine, KV-cached engine, batched scheduler)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, init_params
from repro.specdec import (
    RACE_STRATEGIES,
    SpecDecConfig,
    SpecDecEngine,
    SpecDecServer,
    draft_token_from_uniforms,
    run_block_verify,
)
from repro.specdec.engine import STRATEGIES

K, L, N = 4, 3, 64

TCFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=48,
                   num_heads=4, num_kv_heads=2, head_dim=12, d_ff=96,
                   vocab_size=32, dtype="float32")
DCFG = TCFG.replace(name="d", num_layers=1)


@pytest.fixture(scope="module")
def pair():
    return (init_params(jax.random.PRNGKey(0), TCFG),
            init_params(jax.random.PRNGKey(1), DCFG))


def _engine(pair, strategy, backend, **kw):
    tp, dp = pair
    cfg = SpecDecConfig(num_drafts=2, draft_len=3, strategy=strategy,
                        max_new_tokens=12, top_k=0,
                        verifier_backend=backend, **kw)
    return SpecDecEngine((tp, TCFG), [(dp, DCFG)], cfg)


def _block_inputs(trial, coupled):
    kk = jax.random.fold_in(jax.random.PRNGKey(42), trial)
    ku, kp, kq, ks, kd = jax.random.split(kk, 5)
    log_u = jnp.log(jax.random.uniform(
        ku, (L + 1, K, N), minval=np.finfo(np.float32).tiny, maxval=1.0))
    p = jax.random.dirichlet(kp, jnp.ones(N) * 0.3, (K, L))
    q = jax.random.dirichlet(kq, jnp.ones(N) * 0.3, (K, L + 1))
    strat_keys = jax.random.split(ks, L + 1)
    if coupled:
        d = jnp.stack([draft_token_from_uniforms(log_u[j], p[:, j])
                       for j in range(L)], axis=1)
    else:  # adversarial: uncoupled drafts stress the rejection paths
        d = jax.random.randint(kd, (K, L), 0, N, jnp.int32)
    return log_u, np.asarray(d), p, q, strat_keys


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_fused_matches_legacy_blockwise(strategy):
    """Direct block-level oracle check on synthetic distributions: the
    fused scan (xla AND pallas) reproduces the legacy host loop's tokens,
    acceptance count and final active mask exactly."""
    for trial in range(12):
        args = _block_inputs(trial, coupled=(trial % 2 == 0))
        ref = run_block_verify(*args, strategy=strategy, backend="legacy")
        for backend in ("xla", "pallas"):
            got = run_block_verify(*args, strategy=strategy, backend=backend)
            assert got.new_tokens == ref.new_tokens, (strategy, backend,
                                                      trial)
            assert got.num_accepted == ref.num_accepted
            np.testing.assert_array_equal(got.active, ref.active)
            # The fused path's whole point: ONE host transfer per block.
            assert got.host_syncs == 1


@pytest.mark.slow
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_engine_backends_bit_identical(pair, strategy):
    """End-to-end: the engine emits bit-identical token sequences under
    legacy / xla / pallas verification for every strategy."""
    prompt = np.array([1, 2, 3], np.int32)
    engines = {b: _engine(pair, strategy, b)
               for b in ("legacy", "xla", "pallas")}
    for i in range(3):
        key = jax.random.PRNGKey(100 + i)
        outs = {b: e.generate(key, prompt) for b, e in engines.items()}
        np.testing.assert_array_equal(outs["legacy"].output,
                                      outs["xla"].output, err_msg=strategy)
        np.testing.assert_array_equal(outs["xla"].output,
                                      outs["pallas"].output,
                                      err_msg=strategy)
        assert outs["legacy"].accepted_drafts == outs["xla"].accepted_drafts
        # Fused backends spend exactly one verification transfer per
        # block; the legacy loop pays two per token.
        assert outs["xla"].host_syncs == outs["xla"].blocks
        assert outs["legacy"].host_syncs >= 2 * outs["legacy"].blocks


@pytest.mark.parametrize("strategy", RACE_STRATEGIES)
def test_xla_pallas_row_stats_agree(strategy):
    """The pallas row-race kernel and the jnp fallback produce identical
    race statistics (same score floats, same tie-breaking)."""
    from repro.specdec.block_verify import _race_row_stats
    for trial in range(6):
        log_u, _, _, q, _ = _block_inputs(trial, coupled=True)
        q_steps = jnp.swapaxes(q, 0, 1)
        rx = _race_row_stats(log_u, q_steps, "xla", True)
        rp = _race_row_stats(log_u, q_steps, "pallas", True)
        np.testing.assert_array_equal(np.asarray(rx[0]), np.asarray(rp[0]))
        np.testing.assert_array_equal(np.asarray(rx[1]), np.asarray(rp[1]))


def test_batched_scheduler_matches_sequential(pair):
    """The batched scheduler (one (R*K, T) target forward per round) must
    emit bit-identical outputs to the sequential scheduler, and must do
    exactly ONE target forward per round."""
    prompts = [np.array([1, 2, 3], np.int32),
               np.array([4, 5], np.int32),
               np.array([6, 7, 8, 9], np.int32),
               np.array([2, 4], np.int32)]

    def serve(batched):
        eng = _engine(pair, "gls", "xla")
        server = SpecDecServer(eng, max_batch=3, batched=batched)
        for i, p in enumerate(prompts):
            server.submit(p, max_new=8 if i % 2 == 0 else 6)
        done = server.run(jax.random.PRNGKey(7))
        return server, {r.uid: list(r.output) for r in done}

    seq_server, seq_out = serve(batched=False)
    bat_server, bat_out = serve(batched=True)
    assert seq_out.keys() == bat_out.keys()
    for uid in seq_out:
        assert seq_out[uid] == bat_out[uid], uid
    # Acceptance criterion: one target forward for ALL live requests.
    assert bat_server.metrics.target_forwards == bat_server.metrics.rounds
    assert seq_server.metrics.target_forwards > seq_server.metrics.rounds


def test_batched_scheduler_preserves_request_rng(pair):
    """A request's RNG stream is keyed by (uid, block), never by batch
    position: co-scheduling extra requests must not change its output
    (as long as admission leaves the shared buffer length unchanged)."""
    prompt = np.array([1, 2, 3], np.int32)

    eng1 = _engine(pair, "gls", "xla")
    s1 = SpecDecServer(eng1, max_batch=1, batched=True)
    s1.submit(prompt, max_new=8)
    (r1,) = s1.run(jax.random.PRNGKey(3))

    eng2 = _engine(pair, "gls", "xla")
    s2 = SpecDecServer(eng2, max_batch=3, batched=True)
    s2.submit(prompt, max_new=8)     # uid 1, same (uid, block) RNG stream
    s2.submit(np.array([7, 8], np.int32), max_new=8)
    s2.submit(np.array([3, 1], np.int32), max_new=8)
    done = {r.uid: r for r in s2.run(jax.random.PRNGKey(3))}
    assert list(done[1].output) == list(r1.output)


@pytest.mark.parametrize("strategy", ["gls", "specinfer"])
def test_cached_engine_all_backends(pair, strategy):
    """The KV-cached engine goes through the same dispatcher: its fused
    backends agree with its own legacy backend bit-for-bit."""
    from repro.specdec import CachedSpecDecEngine
    tp, dp = pair
    outs = {}
    for backend in ("legacy", "xla", "pallas"):
        cfg = SpecDecConfig(num_drafts=2, draft_len=3, strategy=strategy,
                            max_new_tokens=10, top_k=0,
                            verifier_backend=backend)
        eng = CachedSpecDecEngine((tp, TCFG), (dp, DCFG), cfg)
        outs[backend] = eng.generate(jax.random.PRNGKey(11),
                                     np.array([1, 2, 3, 4], np.int32))
    np.testing.assert_array_equal(outs["legacy"].output, outs["xla"].output)
    np.testing.assert_array_equal(outs["xla"].output, outs["pallas"].output)
