"""Vocab-sharded GLS verification (shard_map + O(1) collectives) must
match the single-device race exactly.  Runs on a 1-device host mesh in
the main process and on an 8-device mesh in a subprocess (device count
is locked at first jax init, so the multi-device case needs its own
process with XLA_FLAGS)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gls_race.ref import gls_race_ref
from repro.launch.mesh import compat_make_mesh
from repro.specdec.distributed import make_sharded_gls_verify


def _check(mesh):
    k, n = 4, 256
    key = jax.random.PRNGKey(0)
    ku, kq = jax.random.split(key)
    log_u = jnp.log(jax.random.uniform(ku, (k, n), minval=1e-30, maxval=1.0))
    q = jax.random.dirichlet(kq, jnp.ones(n), (k,))
    active = jnp.asarray([True, True, False, True])
    verify = make_sharded_gls_verify(mesh)
    with mesh:
        x, y = verify(log_u, q, active)
    log_s = jnp.log(-log_u)
    xr, yr = gls_race_ref(log_s[None], jnp.log(q)[None], jnp.log(q)[None],
                          active[None])
    np.testing.assert_array_equal(np.asarray(x), np.asarray(xr[0]))
    assert int(y) == int(yr[0])


def test_sharded_verify_single_device():
    mesh = compat_make_mesh((1,), ("model",))
    _check(mesh)


def test_sharded_verify_eight_devices_subprocess():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        sys.path.insert(0, "tests")
        import jax
        from repro.launch.mesh import compat_make_mesh
        from test_distributed_verify import _check
        mesh = compat_make_mesh((8,), ("model",))
        _check(mesh)
        print("SHARDED_OK")
    """)
    env = {**os.environ, "PYTHONPATH": "src"}
    env.pop("XLA_FLAGS", None)  # the script pins its own device count
    out = subprocess.run([sys.executable, "-c", script], cwd=".",
                         capture_output=True, text=True, timeout=300,
                         env=env)
    assert "SHARDED_OK" in out.stdout, out.stderr[-2000:]
