"""Quickstart: Gumbel-max List Sampling in 60 seconds.

Reproduces the paper's core claim on toy distributions: coupling one
target sample with K i.i.d. proposals via shared exponential races makes
the acceptance probability grow with K, bounded below by the List
Matching Lemma (Thm. 1) — while both marginals stay exact.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    gls_sample_batch,
    iid_draft_acceptance_upper,
    lml_bound,
    maximal_coupling_acceptance,
)


def main():
    key = jax.random.PRNGKey(0)
    kp, kq, ks = jax.random.split(key, 3)
    n = 10
    p = jax.random.dirichlet(kp, jnp.ones(n))   # Alice's (draft) dist
    q = jax.random.dirichlet(kq, jnp.ones(n))   # Bob's (target) dist

    print(f"alphabet N={n}, TV(p,q)={0.5 * float(jnp.abs(p - q).sum()):.3f}")
    print(f"maximal coupling (WITH communication, K=1): "
          f"{float(maximal_coupling_acceptance(p, q)):.3f}\n")
    print(f"{'K':>3} {'empirical':>10} {'LML bound':>10} {'upper bound':>12}")
    trials = 20_000
    for k in (1, 2, 4, 8, 16):
        out = gls_sample_batch(ks, p, q, k, trials)
        acc = float(jnp.mean(out.accept))
        lo = float(lml_bound(p, q, k))
        hi = float(iid_draft_acceptance_upper(p, q, k))
        print(f"{k:>3} {acc:>10.3f} {lo:>10.3f} {hi:>12.3f}")
        assert acc >= lo - 0.01, "LML bound violated!"

    # Marginals stay exact no matter what K is.
    out = gls_sample_batch(ks, p, q, 8, trials)
    y_hist = np.bincount(np.asarray(out.y), minlength=n) / trials
    print(f"\nmax |empirical(Y) - q| = "
          f"{float(np.abs(y_hist - np.asarray(q)).max()):.4f}  (exact marginals)")


if __name__ == "__main__":
    main()
