"""Wyner-Ziv compression of a Gaussian source with K decoders (paper
Sec. 5 / Fig. 2): GLS vs the shared-randomness baseline across rates.

Trials stream through the batched compression pipeline
(repro.compression.pipeline): one jitted device program and ONE
gls_binned_race dispatch per chunk of rounds — pass --backend pallas to
race through the Pallas kernel instead of the XLA oracle (bit-identical
outputs either way).

Run:  PYTHONPATH=src python examples/compress_gaussian.py [--backend xla]
"""

import argparse

import jax

from repro.compression import GaussianWZ, run_experiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=("xla", "pallas"), default="xla",
                    help="race backend for the batched pipeline")
    ap.add_argument("--trials", type=int, default=1500)
    args = ap.parse_args()

    cfg = GaussianWZ(sigma2_w_given_a=0.005, n_atoms=4096)
    key = jax.random.PRNGKey(0)
    print(f"pipeline backend: {args.backend}")
    print("rate(bits)  K  GLS match / D(dB)      baseline match / D(dB)"
          "   match bound")
    for l_max in (2, 8, 32):
        for k in (1, 2, 4):
            g = run_experiment(key, cfg, k, l_max, trials=args.trials,
                               backend=args.backend)
            b = run_experiment(key, cfg, k, l_max, trials=args.trials,
                               shared_sheet=True, backend=args.backend)
            print(f"{g['rate_bits']:>9.0f} {k:>3}  "
                  f"{g['match_prob_any']:.3f} / {g['distortion_db']:7.2f}    "
                  f"{b['match_prob_any']:.3f} / {b['distortion_db']:7.2f}"
                  f"    >={g['match_lower_bound']:.3f}")
    print("\nGLS == baseline at K=1; GLS wins for K>1, most at low rates.")
    print("'match bound' is the Prop.-4 lower bound on the GLS "
          "any-decoder match rate (DESIGN.md §10).")


if __name__ == "__main__":
    main()
