"""Wyner-Ziv compression of a Gaussian source with K decoders (paper
Sec. 5 / Fig. 2): GLS vs the shared-randomness baseline across rates.

Run:  PYTHONPATH=src python examples/compress_gaussian.py
"""

import jax

from repro.compression import GaussianWZ, run_experiment


def main():
    cfg = GaussianWZ(sigma2_w_given_a=0.005, n_atoms=4096)
    key = jax.random.PRNGKey(0)
    print("rate(bits)  K  GLS match / D(dB)      baseline match / D(dB)")
    for l_max in (2, 8, 32):
        for k in (1, 2, 4):
            g = run_experiment(key, cfg, k, l_max, trials=1500)
            b = run_experiment(key, cfg, k, l_max, trials=1500,
                               shared_sheet=True)
            print(f"{g['rate_bits']:>9.0f} {k:>3}  "
                  f"{g['match_prob_any']:.3f} / {g['distortion_db']:7.2f}    "
                  f"{b['match_prob_any']:.3f} / {b['distortion_db']:7.2f}")
    print("\nGLS == baseline at K=1; GLS wins for K>1, most at low rates.")


if __name__ == "__main__":
    main()
