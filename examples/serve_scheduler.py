"""Production-path serving demo: the batched request scheduler over the
GLS speculative-decoding engine, with serving metrics (tokens/s, mean
block efficiency, per-request latencies).

Runs the same request trace through ALL THREE cache modes — sequential
(stateless reference engine, full-prefix re-score, one engine block per
request per round), kv (persistent KV caches in a multi-request slot
pool, no per-block re-prefill, DESIGN.md §7), and kv_fused (the same
pool with every round fused into ONE jitted device program, DESIGN.md
§8) — and checks their outputs are bit-identical while reporting the
tokens/s deltas and per-round sync counts (the fused mode's signature:
0 draft syncs and exactly 1 host sync per round).

Run:  PYTHONPATH=src python examples/serve_scheduler.py [--requests 6]
"""

import argparse

import jax
import numpy as np

from repro.data import encode, lm_dataset, synthetic_corpus
from repro.models import ModelConfig, init_params
from repro.specdec import (
    CachedSpecDecEngine,
    SpecDecConfig,
    SpecDecEngine,
    SpecDecServer,
)
from repro.train import TrainConfig, train

VOCAB = 128
TARGET = ModelConfig(name="sched-target", family="dense", num_layers=3,
                     d_model=192, num_heads=6, num_kv_heads=2, head_dim=32,
                     d_ff=384, vocab_size=VOCAB, dtype="float32")
DRAFTER = ModelConfig(name="sched-drafter", family="dense", num_layers=1,
                      d_model=96, num_heads=4, num_kv_heads=2, head_dim=24,
                      d_ff=192, vocab_size=VOCAB, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=3)
    args = ap.parse_args()

    print("== training pair ==")
    tc = TrainConfig(total_steps=args.steps, log_every=args.steps // 2,
                     lr=1e-3)
    tp, _ = train(init_params(jax.random.PRNGKey(0), TARGET), TARGET, tc,
                  lm_dataset(16, 96, VOCAB, seed=0, num_sentences=4000))
    dp, _ = train(init_params(jax.random.PRNGKey(1), DRAFTER), DRAFTER,
                  TrainConfig(total_steps=args.steps // 2, lr=1e-3,
                              log_every=args.steps),
                  lm_dataset(16, 96, VOCAB, seed=1, num_sentences=4000))

    corpus = encode(synthetic_corpus(60, seed=11)) % VOCAB

    sd = SpecDecConfig(num_drafts=4, draft_len=3, strategy="gls", top_k=50)

    def serve(mode):
        if mode in ("kv", "kv_fused"):
            eng = CachedSpecDecEngine((tp, TARGET), (dp, DRAFTER), sd,
                                      pool_slots=args.max_batch)
            server = SpecDecServer(eng, max_batch=args.max_batch,
                                   cache_mode=mode)
        else:
            eng = SpecDecEngine((tp, TARGET), [(dp, DRAFTER)], sd)
            server = SpecDecServer(eng, max_batch=args.max_batch)
        for i in range(args.requests):
            server.submit(corpus[i * 29:i * 29 + 12], max_new=args.max_new)
        done = server.run(jax.random.PRNGKey(7))
        return server, done

    outputs = {}
    for mode in ("sequential", "kv", "kv_fused"):
        print(f"\n== serving {args.requests} requests "
              f"(max_batch={args.max_batch}, cache_mode={mode}) ==")
        server, done = serve(mode)
        for r in done:
            lat = (r.t_done - r.t_submit)
            print(f"req {r.uid}: {len(r.output)} tokens, "
                  f"BE={r.block_efficiency:.2f}, ttft={r.ttft_ms:.0f}ms, "
                  f"latency={lat:.1f}s")
        m = server.metrics
        print(f"throughput: {m.tokens_per_s:.1f} tok/s  "
              f"mean BE: {m.mean_block_efficiency:.2f}  "
              f"completed: {m.completed}  rounds: {m.rounds}  "
              f"target-forwards: {m.target_forwards}")
        print(f"syncs/round: draft={m.draft_syncs / m.rounds:.1f}  "
              f"host={m.host_syncs / m.rounds:.1f}  "
              f"(totals: draft={m.draft_syncs} host={m.host_syncs} "
              f"over {m.rounds} rounds)")
        outputs[mode] = {r.uid: list(r.output) for r in done}

    for mode in ("kv", "kv_fused"):
        match = outputs["sequential"] == outputs[mode]
        print(f"\n{mode} output == sequential output: {match}")
        if not match:
            raise SystemExit(f"scheduler paths diverged ({mode})!")


if __name__ == "__main__":
    main()
