"""End-to-end driver: train a target LM + a small drafter on the synthetic
corpus, then SERVE a batch of requests with drafter-invariant multi-draft
speculative decoding (paper Alg. 2), comparing block efficiency across
verification strategies — and verification backends: the legacy per-token
host loop vs the fused device-side block verifier ("xla"), vs the fused
verifier racing through the Pallas gls_race kernel ("pallas").

Run:  PYTHONPATH=src python examples/serve_specdec.py [--steps 150]
                                                      [--backend xla]
"""

import argparse
import time

import jax
import numpy as np

from repro.data import decode as detok
from repro.data import encode, lm_dataset, synthetic_corpus
from repro.models import ModelConfig, init_params
from repro.specdec import SpecDecConfig, SpecDecEngine
from repro.train import TrainConfig, train

VOCAB = 128

TARGET = ModelConfig(name="serve-target", family="dense", num_layers=4,
                     d_model=256, num_heads=8, num_kv_heads=4, head_dim=32,
                     d_ff=512, vocab_size=VOCAB, dtype="float32")
DRAFTER = ModelConfig(name="serve-drafter", family="dense", num_layers=2,
                      d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
                      d_ff=256, vocab_size=VOCAB, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--backend", default="xla",
                    choices=("legacy", "xla", "pallas"),
                    help="verifier backend for the strategy table")
    args = ap.parse_args()

    print("== training target + drafter on the synthetic corpus ==")
    tparams = init_params(jax.random.PRNGKey(0), TARGET)
    dparams = init_params(jax.random.PRNGKey(1), DRAFTER)
    tc = TrainConfig(total_steps=args.steps, log_every=max(args.steps // 3, 1),
                     lr=1e-3)
    tparams, _ = train(tparams, TARGET, tc,
                       lm_dataset(16, 128, VOCAB, seed=0, num_sentences=6000))
    dparams, _ = train(dparams, DRAFTER, tc,
                       lm_dataset(16, 128, VOCAB, seed=1, num_sentences=6000))

    corpus = encode(synthetic_corpus(40, seed=9)) % VOCAB
    prompts = [np.asarray(corpus[i * 53:i * 53 + 16], np.int32)
               for i in range(args.requests)]

    def measure(strategy, k, backend):
        eng = SpecDecEngine(
            (tparams, TARGET), [(dparams, DRAFTER)],
            SpecDecConfig(num_drafts=k, draft_len=4, strategy=strategy,
                          top_k=50, max_new_tokens=args.max_new,
                          verifier_backend=backend))
        t0 = time.time()
        results = eng.serve(jax.random.PRNGKey(7), prompts)
        dt = time.time() - t0
        toks = sum(len(r.output) for r in results)
        return results, dt, toks / max(dt, 1e-9), \
            sum(r.host_syncs for r in results)

    print(f"\n== serving batched requests (backend={args.backend}) ==")
    for strategy in ("gls", "specinfer", "daliri"):
        k = 1 if strategy == "daliri" else 8
        results, dt, tps, syncs = measure(strategy, k, args.backend)
        be = float(np.mean([r.block_efficiency for r in results]))
        print(f"{strategy:10s} K={k}  BE={be:.2f}  {tps:6.1f} tok/s  "
              f"verify-syncs={syncs}  ({dt:.1f}s for {len(prompts)} "
              f"requests)")
        if strategy == "gls":
            sample = detok(results[0].output)
            print(f"           sample output: {sample[:72]!r}")

    print("\n== verifier backends (gls, K=8): host-sync and tokens/s "
          "deltas ==")
    base_tps = None
    for backend in ("legacy", "xla", "pallas"):
        results, dt, tps, syncs = measure("gls", 8, backend)
        base_tps = base_tps or tps
        print(f"{backend:8s} {tps:6.1f} tok/s ({tps / base_tps:4.2f}x)  "
              f"verify-syncs={syncs}")


if __name__ == "__main__":
    main()
