"""End-to-end driver: train a target LM + a small drafter on the synthetic
corpus, then SERVE a batch of requests with drafter-invariant multi-draft
speculative decoding (paper Alg. 2), comparing block efficiency across
verification strategies.

Run:  PYTHONPATH=src python examples/serve_specdec.py [--steps 150]
"""

import argparse
import time

import jax
import numpy as np

from repro.data import decode as detok
from repro.data import encode, lm_dataset, synthetic_corpus
from repro.models import ModelConfig, init_params
from repro.specdec import SpecDecConfig, SpecDecEngine
from repro.train import TrainConfig, train

VOCAB = 128

TARGET = ModelConfig(name="serve-target", family="dense", num_layers=4,
                     d_model=256, num_heads=8, num_kv_heads=4, head_dim=32,
                     d_ff=512, vocab_size=VOCAB, dtype="float32")
DRAFTER = ModelConfig(name="serve-drafter", family="dense", num_layers=2,
                      d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
                      d_ff=256, vocab_size=VOCAB, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=48)
    args = ap.parse_args()

    print("== training target + drafter on the synthetic corpus ==")
    tparams = init_params(jax.random.PRNGKey(0), TARGET)
    dparams = init_params(jax.random.PRNGKey(1), DRAFTER)
    tc = TrainConfig(total_steps=args.steps, log_every=max(args.steps // 3, 1),
                     lr=1e-3)
    tparams, _ = train(tparams, TARGET, tc,
                       lm_dataset(16, 128, VOCAB, seed=0, num_sentences=6000))
    dparams, _ = train(dparams, DRAFTER, tc,
                       lm_dataset(16, 128, VOCAB, seed=1, num_sentences=6000))

    corpus = encode(synthetic_corpus(40, seed=9)) % VOCAB
    prompts = [np.asarray(corpus[i * 53:i * 53 + 16], np.int32)
               for i in range(args.requests)]

    print("\n== serving batched requests ==")
    for strategy in ("gls", "specinfer", "daliri"):
        k = 1 if strategy == "daliri" else 8
        eng = SpecDecEngine(
            (tparams, TARGET), [(dparams, DRAFTER)],
            SpecDecConfig(num_drafts=k, draft_len=4, strategy=strategy,
                          top_k=50, max_new_tokens=args.max_new))
        t0 = time.time()
        results = eng.serve(jax.random.PRNGKey(7), prompts)
        dt = time.time() - t0
        be = float(np.mean([r.block_efficiency for r in results]))
        print(f"{strategy:10s} K={k}  BE={be:.2f}  "
              f"({dt:.1f}s for {len(prompts)} requests)")
        if strategy == "gls":
            sample = detok(results[0].output)
            print(f"           sample output: {sample[:72]!r}")


if __name__ == "__main__":
    main()
