"""Train an LM from any of the 10 assigned architectures (reduced variant)
for a few hundred steps on the synthetic corpus, with checkpointing.

Run:  PYTHONPATH=src python examples/train_lm.py --arch smollm-360m \
          --steps 200 [--full]   (--full uses the published config; only
          sensible on real hardware)
"""

import argparse

import jax

from repro.configs import ARCH_NAMES, get_config
from repro.data import lm_dataset
from repro.models import init_params, param_count
from repro.train import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ARCH_NAMES)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit(
            f"{args.arch} needs frames/images inputs; this text-LM example "
            f"covers decoder-only archs — see tests/test_arch_smoke.py for "
            f"the {cfg.family} train step.")

    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"arch={cfg.name} params={param_count(params):,}")
    ds = lm_dataset(args.batch, args.seq, cfg.vocab_size, num_sentences=8000)
    tc = TrainConfig(total_steps=args.steps, log_every=max(args.steps // 10, 1))
    params, hist = train(params, cfg, tc, ds,
                         checkpoint_path=args.checkpoint)
    print(f"final loss: {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
