"""β-VAE distributed image compression on synthetic MNIST-like digits
(paper Sec. 5 / Fig. 3-4): train the codec nets, then compress the right
half of each image for K decoders holding 7x7 left-half crops.

Coding runs through the batched compression pipeline
(repro.compression.pipeline): net forwards, stacked race tables and ONE
gls_binned_race dispatch per batch of images in a single jitted program
(--backend pallas races through the Pallas kernel, bit-identically).

Run:  PYTHONPATH=src python examples/compress_mnist.py [--steps 400]
"""

import argparse

import jax
import numpy as np

from repro.compression import VAETrainConfig, evaluate_rd, train_vae
from repro.data.mnist import digits_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--backend", choices=("xla", "pallas"), default="xla",
                    help="race backend for the batched pipeline")
    args = ap.parse_args()

    imgs, _ = digits_dataset(3000, seed=0)
    print(f"== training beta-VAE codec ({args.steps} steps) ==")
    params = train_vae(jax.random.PRNGKey(0), imgs,
                       VAETrainConfig(steps=args.steps, beta=0.35))

    test, _ = digits_dataset(400, seed=1)
    print(f"\npipeline backend: {args.backend}")
    print("rate(bits)  K  GLS mse/match     baseline mse/match")
    for l_max in (4, 16, 64):
        for k in (1, 2):
            g = evaluate_rd(jax.random.PRNGKey(1), params, test,
                            n_atoms=256, l_max=l_max, k=k, trials=48,
                            backend=args.backend)
            b = evaluate_rd(jax.random.PRNGKey(1), params, test,
                            n_atoms=256, l_max=l_max, k=k, trials=48,
                            shared_sheet=True, backend=args.backend)
            print(f"{np.log2(l_max):>9.0f} {k:>3}  "
                  f"{g['mse']:.4f}/{g['match_prob_any']:.2f}        "
                  f"{b['mse']:.4f}/{b['match_prob_any']:.2f}")


if __name__ == "__main__":
    main()
